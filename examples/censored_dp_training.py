"""End-to-end driver (deliverable b): train a ~100M-param qwen3-family
model for a few hundred steps with COKE consensus data-parallelism, and
compare against standard all-reduce DP on the same token stream.

The agent axis is the paper's network: each agent sees a disjoint shard of
every batch, runs an inexact ADMM primal step (one AdamW step on the
augmented Lagrangian), censors its broadcast by ||θ−θ̂|| >= v·μ^k, and
exchanges θ̂ with its ring neighbors (lax-level: jnp.roll over the stacked
agent axis → collective-permute on a real mesh).

Run:  PYTHONPATH=src python examples/censored_dp_training.py [--steps 300]
(~100M params; a few hundred steps takes tens of minutes on CPU — use
--small for a quick pass.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

# the deep-net consensus-DP surface is re-exported by repro.api so this
# driver shares one import surface with the KRR fit() scripts
from repro.api import (Censor, Chain, ConsensusConfig, OptConfig, Quantize,
                       agent_batch, make_train_step)
from repro.configs import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true",
                help="2-layer reduced variant for a quick smoke pass")
args = ap.parse_args()

# ~100M params: 8 layers, d=768, vocab 32k (qwen3 family, scaled down)
cfg = get_config("qwen3-1.7b").with_overrides(
    num_layers=8, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, attn_block_q=128, attn_block_k=128)
if args.small:
    cfg = cfg.reduced()
n_params = sum(x.size for x in jax.tree.leaves(
    jax.eval_shape(lambda k: __import__("repro.models.model",
                                        fromlist=["init_params"])
                   .init_params(cfg, k), jax.random.PRNGKey(0))))
print(f"model: {cfg.name} variant, {n_params/1e6:.1f}M params")

N_AGENTS = 4
B, S = 8, 128 if not args.small else 32
opt = OptConfig(kind="adamw", lr=1e-3, grad_clip=1.0)
stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                       seq_len=S, global_batch=B,
                                       structure=0.9))

runs = {}
for label, ccfg, comm in [
    ("allreduce", None, None),
    ("coke", ConsensusConfig(strategy="coke", rho=1e-3, censor_v=5.0,
                             censor_mu=0.995), None),
    # censoring composed with 8-bit stochastic innovation quantization:
    # same ADMM math, ~4x fewer bits per surviving broadcast
    ("coke-q8", ConsensusConfig(strategy="coke", rho=1e-3),
     Chain([Censor(v=5.0, mu=0.995), Quantize(bits=8)])),
]:
    init_fn, step_fn, _ = make_train_step(cfg, opt, ccfg,
                                          num_agents=N_AGENTS, comm=comm)
    state = init_fn(jax.random.PRNGKey(0))
    step_j = jax.jit(step_fn)
    losses, t0 = [], time.time()
    for i in range(args.steps):
        toks, labels = stream.batch(i)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if ccfg is not None:
            batch = agent_batch(batch, N_AGENTS)
        state, m = step_j(state, batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0:
            extra = ""
            if ccfg is not None:
                extra = (f" gap={float(m['consensus_gap']):.3f}"
                         f" comms={int(m['comms'])}")
                if "bits" in m:
                    extra += f" GB={float(m['bits'])/8e9:.2f}"
            print(f"[{label}] step {i:4d} loss={losses[-1]:.4f}{extra}",
                  flush=True)
    runs[label] = {"final_loss": losses[-1],
                   "wall_s": time.time() - t0,
                   "comms": int(m.get("comms", args.steps * N_AGENTS)),
                   "bits": int(m["bits"]) if "bits" in m else None}

print("\nsummary:")
for label, r in runs.items():
    gb = f" sent={r['bits']/8e9:.2f}GB" if r["bits"] is not None else ""
    print(f"  {label:10s} final_loss={r['final_loss']:.4f} "
          f"wall={r['wall_s']:.0f}s transmissions={r['comms']}{gb}")
ideal = args.steps * N_AGENTS
print(f"  COKE censored {1 - runs['coke']['comms']/ideal:.0%} of the "
      f"{ideal} possible transmissions.")
if runs["coke"]["bits"] and runs["coke-q8"]["bits"]:
    print(f"  8-bit quantization cut the surviving broadcasts' bytes "
          f"{runs['coke']['bits'] / runs['coke-q8']['bits']:.1f}x further.")
