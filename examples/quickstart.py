"""Quickstart: the paper in ~50 lines, through the unified `repro.api`.

Decentralized kernel ridge regression over 12 agents on a random connected
graph — DKLA (Alg. 1), COKE (Alg. 2), the CTA diffusion baseline, and the
centralized closed-form oracle they must all converge to, all via one
registry and one `fit()` — then the fitted function exported as a
deployable `KernelModel` (predict / evaluate / save).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.api import (Censor, Chain, Drop, FitConfig, KernelModel,
                       KRRConfig, Quantize, build_problem, fit,
                       list_solvers)

base = FitConfig(
    krr=KRRConfig(num_agents=12, samples_per_agent=300, num_features=64,
                  lam=1e-3, rho=5e-2, seed=0),
    censor_v=0.1, censor_mu=0.995, num_iters=500)

# One problem (local data, graph, common-seed random features), shared by
# every algorithm in the registry.
built = build_problem(base)
print(f"graph: N={built.graph.num_agents} agents, {built.graph.num_edges} "
      f"edges, connected={built.graph.is_connected()}")
print(f"registered solvers: {', '.join(list_solvers())}")

# Centralized oracle (Eq. 26) — what decentralized learning must reach.
theta_star = fit(base.replace(algorithm="ridge_oracle", num_iters=1),
                 problem=built.problem).theta[0]

results = {name: fit(base.replace(algorithm=name), problem=built.problem)
           for name in ("dkla", "coke", "cta")}

print(f"\n{'':10s}{'train MSE':>12s}{'dist to θ*':>12s}{'# transmissions':>18s}")
for name, r in results.items():
    print(f"{name.upper():10s}{float(r.train_mse[-1]):12.3e}"
          f"{r.distance_to(theta_star):12.3e}{int(r.comms[-1]):18d}")

saving = 1 - int(results["coke"].comms[-1]) / int(results["dkla"].comms[-1])
print(f"\nCOKE transmits {saving:.0%} less than DKLA at comparable accuracy "
      f"(paper reports ~45-55% on its datasets; benchmarks/paper_comm_cost.py"
      f"\nreproduces the tuned per-dataset protocol).")

# communication is a composable POLICY axis: the same censor rule stacked
# with 4-bit stochastic innovation quantization and 5% link drops, with
# the cost metric moved from transmissions to bits
q4 = fit(base.replace(
    censor_v=None, censor_mu=None, algorithm="coke",
    comm=Chain([Censor(v=0.1, mu=0.995), Quantize(bits=4), Drop(p=0.05)])),
    problem=built.problem)
bits_saving = 1 - float(q4.bits[-1]) / float(results["coke"].bits[-1])
print(f"censor+4-bit+drops: train MSE {float(q4.train_mse[-1]):.3e} at "
      f"{int(q4.bits[-1]):,} bits\n— {bits_saving:.0%} fewer bits than "
      f"full-precision COKE ({int(results['coke'].bits[-1]):,}).")

# fit → deploy: package the fitted function as a KernelModel — the RFF map
# plus the consensus theta is everything a serving node needs.
model = results["coke"].to_model(built.rff_params)
metrics = model.evaluate(built.x_test, built.y_test)
with tempfile.TemporaryDirectory() as d:
    model.save(f"{d}/coke")
    reloaded = KernelModel.load(f"{d}/coke")
preds = reloaded.predict(built.x_test[0][:3])
print(f"\nKernelModel: test MSE {metrics['test_mse']:.3e}, saved+reloaded, "
      f"f(x) on 3 held-out points: {[f'{float(p):.3f}' for p in preds]}"
      f"\n(examples/serve_kernel.py serves this artifact under concurrent "
      f"traffic)")

# the same COKE config on the SPMD ring runtime (collective-permute
# semantics) — one config axis, not a different codebase:
ring_cfg = base.replace(algorithm="coke", graph="ring", backend="spmd",
                        primal="gradient", inner_steps=1, inner_lr=0.05,
                        num_iters=200)
ring = fit(ring_cfg, problem=build_problem(ring_cfg).problem)
print(f"\nSPMD ring backend: COKE train MSE "
      f"{float(ring.train_mse[-1]):.3e} with {int(ring.comms[-1])} "
      f"transmissions in {len(ring.train_mse)} iters")
