"""Quickstart: the paper in ~60 lines.

Decentralized kernel ridge regression over 12 agents on a random connected
graph — DKLA (Alg. 1), COKE (Alg. 2), the CTA diffusion baseline, and the
centralized closed-form oracle they must all converge to.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.coke_krr import KRRConfig
from repro.core import admm, cta, graph, rff, ridge
from repro.core.censor import CensorSchedule
from repro.data.synthetic import paper_synthetic

cfg = KRRConfig(num_agents=12, samples_per_agent=300, num_features=64,
                lam=1e-3, rho=5e-2, censor_v=0.1, censor_mu=0.995)

# 1. Locally observed data — never exchanged between agents.
ds = paper_synthetic(num_agents=cfg.num_agents,
                     samples_per_agent=cfg.samples_per_agent, seed=0)
g = graph.erdos_renyi(cfg.num_agents, cfg.graph_p, seed=1)
print(f"graph: N={g.num_agents} agents, {g.num_edges} edges, "
      f"connected={g.is_connected()}")

# 2. Common-seed random features: the data-independent parameterization
#    that makes consensus possible (Section 3.1).
p = rff.draw_rff(jax.random.PRNGKey(cfg.seed), ds.input_dim,
                 cfg.num_features, cfg.bandwidth)
feats = rff.featurize(p, jnp.asarray(ds.x))      # (N, T_i, L)
labels = jnp.asarray(ds.y)

# 3. Centralized oracle (Eq. 26) — what decentralized learning must reach.
theta_star = ridge.rf_ridge(feats, labels, cfg.lam)
prob = admm.make_problem(feats, labels, g, lam=cfg.lam, rho=cfg.rho)

# 4. Run all three algorithms.
iters = 500
res_dkla = admm.run(prob, admm.dkla_schedule(), iters)
res_coke = admm.run(prob, CensorSchedule(cfg.censor_v, cfg.censor_mu),
                    iters)
res_cta = cta.run(prob, g, lr=0.9, num_iters=iters)


def dist(theta_stack):
    return float(jnp.max(jnp.linalg.norm(theta_stack - theta_star, -1)))


print(f"\n{'':10s}{'train MSE':>12s}{'dist to θ*':>12s}{'# transmissions':>18s}")
for name, r in [("DKLA", res_dkla), ("COKE", res_coke)]:
    print(f"{name:10s}{float(r.train_mse[-1]):12.3e}"
          f"{dist(r.state.theta):12.3e}{int(r.comms[-1]):18d}")
print(f"{'CTA':10s}{float(res_cta.train_mse[-1]):12.3e}"
      f"{'—':>12s}{int(res_cta.comms[-1]):18d}")

saving = 1 - int(res_coke.comms[-1]) / int(res_dkla.comms[-1])
print(f"\nCOKE transmits {saving:.0%} less than DKLA at comparable accuracy "
      f"(paper reports ~45-55% on its datasets; benchmarks/paper_comm_cost.py"
      f"\nreproduces the tuned per-dataset protocol).")
