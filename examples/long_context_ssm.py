"""Long-context streaming decode with O(1) state (the `long_500k` shape's
CPU-scale demonstration): a reduced Mamba2 decodes thousands of tokens with
constant memory, and the recurrent state matches a fresh full-sequence
forward at every probe point.

Run:  PYTHONPATH=src python examples/long_context_ssm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M

cfg = get_config("mamba2-2.7b").reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0))
B = 1
state = M.init_serve_state(cfg, B, cache_len=1)  # SSM: cache_len irrelevant

decode = jax.jit(lambda p, t, s, pos: M.decode_step(p, cfg, t, s, pos))

rng = np.random.default_rng(0)
STREAM = 3000
toks = rng.integers(0, cfg.vocab_size, (B, STREAM)).astype(np.int32)

t0 = time.time()
probes = {}
for t in range(STREAM):
    logits, state = decode(params, jnp.asarray(toks[:, t:t + 1]), state,
                           jnp.asarray(t, jnp.int32))
    if t + 1 in (500, 1500, 3000):
        probes[t + 1] = np.asarray(logits[0, 0, :8])
dt = time.time() - t0

state_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
print(f"streamed {STREAM} tokens in {dt:.1f}s "
      f"({STREAM / dt:.0f} tok/s on CPU); "
      f"recurrent state = {state_bytes / 1024:.1f} KiB, constant.")

# verify against a fresh full forward at the last probe
batch = {"tokens": jnp.asarray(toks[:, :3000]),
         "labels": jnp.asarray(toks[:, :3000])}
logits_full, _ = M.forward(params, cfg, batch)
err = float(jnp.max(jnp.abs(logits_full[0, -1, :8] - probes[3000])))
print(f"decode-vs-forward max |Δlogit| at t=3000: {err:.2e} "
      f"({'OK' if err < 2e-2 else 'MISMATCH'})")
