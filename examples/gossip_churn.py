"""Async gossip with churn: partial participation, a straggler, and an
agent that leaves mid-stream and later rejoins.

Twenty agents run online-COKE over a stationary stream under
`exec="gossip"`: each round a ~50% Bernoulli sample of agents computes
and (subject to censoring) broadcasts, everyone else holds state and pays
zero bits. A `ChurnSchedule` scripts the scenario — agent 7 leaves at
round 50 and rejoins at round 100 (re-entering with zeroed state, then
re-converging through its neighbors), while agent 3 runs 3x slow and so
participates ~3x less often. The asserts at the bottom pin the headline
behavior: regret recovers after the rejoin, and sampling + censoring
together pay far fewer transmissions than sync always-broadcast would.

Run:  PYTHONPATH=src python examples/gossip_churn.py
"""
import numpy as np

from repro.api import ChurnSchedule, FitConfig, KRRConfig, fit_stream

ROUNDS = 160
LEAVE, REJOIN = 50, 100

base = FitConfig(
    krr=KRRConfig(num_agents=20, num_features=64, lam=1e-3, rho=5e-2,
                  seed=0),
    graph="ring", algorithm="online_coke", stream="stationary",
    num_iters=ROUNDS, online_batch=8, online_lr=0.3,
    censor_v=0.2, censor_mu=0.995)

churn = ChurnSchedule(leave=((LEAVE, 7),), join=((REJOIN, 7),),
                      slowdown=((3, 3.0),))
gossip = base.replace(exec="gossip", participation=0.5, churn=churn)

sync = fit_stream(base)
gsp = fit_stream(gossip)

inst = np.asarray(gsp.history["instant_mse"], np.float64)
print(f"{'round window':>16s}{'gossip regret':>15s}")
for lo, hi, tag in ((0, 10, "cold start"), (LEAVE - 10, LEAVE, "pre-leave"),
                    (REJOIN, REJOIN + 10, "rejoin shock"),
                    (ROUNDS - 10, ROUNDS, "recovered")):
    print(f"{lo:>6d}-{hi:<4d} {tag:>10s}{inst[lo:hi].mean():15.3e}")

bits = np.asarray(gsp.state.inner.comm.bits)
print(f"\nstraggler (agent 3, 3x slow) paid {int(bits[3]):,} bits vs "
      f"{int(bits.mean()):,} mean;\nchurned agent 7 paid "
      f"{int(bits[7]):,} (absent rounds {LEAVE}-{REJOIN - 1})")
print(f"transmissions: gossip {int(gsp.comms[-1])} vs sync "
      f"{int(sync.comms[-1])} (sampling + censoring stack)")

# the demo's contract, pinned --------------------------------------------
late = inst[-10:].mean()
assert late < inst[:10].mean(), "regret must recover after the rejoin"
assert late < 2.0 * inst[LEAVE - 10:LEAVE].mean(), \
    "post-rejoin regret must return to the pre-leave level"
assert bits[3] < 0.7 * bits.mean(), "the straggler must pay fewer bits"
assert int(gsp.comms[-1]) < int(sync.comms[-1]), \
    "partial participation must save transmissions over sync"
print("\nOK: regret recovered after churn; gossip saved transmissions.")
