"""Many-model serving: publish per-user models to a `ModelRegistry`, serve
tagged traffic for all of them from ONE `KernelServer`, then hot-swap a
model under live traffic.

Every model shares the common-seed RFF featurizer, so a user's model is
just its (D,) theta — the server keeps thousands resident as one (M, D)
`ThetaStore` stack, gathers each request's row inside the same jitted
scorer, and pages overflow tenants against the registry on disk.

Run:  PYTHONPATH=src python examples/serve_many.py
"""
import dataclasses
import tempfile

import numpy as np

from repro.api import FitConfig, KRRConfig, fit
from repro.serve import (KernelServeConfig, KernelServer, ModelRegistry,
                         ThetaStore)

config = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=80, num_features=32,
                  lam=1e-3, rho=5e-2, seed=0),
    algorithm="coke", censor_v=0.1, censor_mu=0.995, num_iters=60)

# one shared fit -> the base artifact every per-user model derives from
base = fit(config).to_model()
rng = np.random.default_rng(7)

NUM_USERS = 200
ids = [f"user-{i:04d}" for i in range(NUM_USERS)]

with tempfile.TemporaryDirectory() as root:
    # 1. publish: each user's personalized theta becomes a versioned,
    #    bit-identical registry artifact (npz + JSON sidecar).
    registry = ModelRegistry(root)
    thetas = {}
    for mid in ids:
        theta = (np.asarray(base.theta)
                 + rng.normal(scale=0.05, size=base.num_features)
                 ).astype(np.float32)
        thetas[mid] = theta
        registry.publish(mid, dataclasses.replace(
            base, theta=theta, thetas=None))
    print(f"registry: {len(registry.models())} models published under "
          f"{root}")

    # 2. serve all of them from one process: a store smaller than the
    #    catalog pages cold tenants in from the registry on demand.
    store = ThetaStore(64, base.num_features)
    with KernelServer(model=base, registry=registry, store=store,
                      config=KernelServeConfig(max_delay_ms=2.0)) as server:
        x = rng.uniform(size=(4, base.input_dim)).astype(np.float32)
        futures = [(mid, server.submit(x, mid))
                   for mid in rng.choice(ids, size=100)]
        for mid, fut in futures:
            y = fut.result()
            # every tagged answer is bit-identical to its model's own
            # row-wise reference, no matter who shared its device batch
            ref = np.asarray(base.score_rows(
                x, np.broadcast_to(thetas[mid], (4, base.num_features))))
            assert np.array_equal(np.asarray(y), ref), mid
        s = server.stats()
        print(f"served {len(futures)} tagged requests across "
              f"{len({m for m, _ in futures})} tenants in "
              f"{s['batches']} device calls "
              f"(store: {s['store']['resident']}/{s['store']['capacity']} "
              f"resident, {s['store']['faults']} faults, "
              f"{s['store']['evictions']} evictions)")

        # 3. hot-swap: publish a refined theta for one user; the very next
        #    tagged request scores with it — no restart, no retrace.
        target = ids[0]
        before = np.asarray(server.predict(x, target))
        new_theta = (thetas[target] * 0.5).astype(np.float32)
        version = server.publish(target, new_theta)
        after = np.asarray(server.predict(x, target))
        ref = np.asarray(base.score_rows(
            x, np.broadcast_to(new_theta, (4, base.num_features))))
        assert np.array_equal(after, ref)
        assert not np.array_equal(before, after)
        print(f"hot-swap: {target} v{version} live "
              f"(first row {before[0]:+.4f} -> {after[0]:+.4f})")

print("serve_many OK")
