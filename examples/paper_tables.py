"""Reproduce the paper's Table 1/3-style comparison on one command.

Prints MSE-vs-iteration and comms-to-target tables for CTA / DKLA / COKE on
the synthetic setup of Section 5.1.

Run:  PYTHONPATH=src python examples/paper_tables.py  (from the repo root)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_comm_cost import run_setup as comm_rows  # noqa: E402
from benchmarks.paper_convergence import run_setup as conv_rows  # noqa: E402

print("=== MSE vs iteration (Table 1/2/4/5 protocol, synthetic) ===")
print(f"{'k':>6s} {'CTA':>12s} {'DKLA':>12s} {'COKE':>12s} "
      f"{'COKE comms':>12s}")
for r in conv_rows("synthetic", iters=600, samples=300):
    print(f"{r['iteration']:6d} {r['cta_mse']:12.3e} {r['dkla_mse']:12.3e} "
          f"{r['coke_mse']:12.3e} {r['coke_comms']:12d}")

print("\n=== comms to reach target MSE (Table 3/6 protocol) ===")
print(f"{'target':>12s} {'CTA':>8s} {'DKLA':>8s} {'COKE':>8s} {'saving':>8s}")
rows, _summary = comm_rows("synthetic", iters=800, samples=300)
for r in rows:
    cta = r["cta"] if r["cta"] is not None else "—"
    dk, ck = r["dkla"], r["coke"]
    saving = f"{1 - ck / dk:.0%}" if (dk and ck) else "—"
    print(f"{r['target_mse']:12.3e} {str(cta):>8s} {str(dk):>8s} "
          f"{str(ck):>8s} {saving:>8s}")
