"""Fit → deploy in one script: train COKE, export a `KernelModel`, save and
reload the artifact, then serve concurrent scoring traffic through the
microbatching `KernelServer`.

Run:  PYTHONPATH=src python examples/serve_kernel.py
"""
import tempfile
import threading
import time

import numpy as np

from repro.api import FitConfig, KernelModel, KRRConfig, build_problem, fit
from repro.serve import KernelServeConfig, KernelServer

config = FitConfig(
    krr=KRRConfig(num_agents=8, samples_per_agent=200, num_features=64,
                  lam=1e-3, rho=5e-2, seed=0),
    algorithm="coke", censor_v=0.1, censor_mu=0.995, num_iters=300)

# fit → to_model(): the deployable artifact is just (RFF map, theta).
built = build_problem(config)
result = fit(config, problem=built.problem)
model = result.to_model(built.rff_params)
metrics = model.evaluate(built.x_test, built.y_test)
print(f"fitted: train MSE {float(result.train_mse[-1]):.3e}, "
      f"test MSE {metrics['test_mse']:.3e} "
      f"(consensus theta: {metrics['consensus_mse']:.3e})")

# save / load round-trips the artifact (npz + JSON sidecar).
with tempfile.TemporaryDirectory() as d:
    model.save(f"{d}/coke_model")
    model = KernelModel.load(f"{d}/coke_model")
print(f"artifact: {model.meta['algorithm']} on {model.meta['dataset']}, "
      f"L={model.num_features}, h(k)={model.meta['censor_v']}"
      f"*{model.meta['censor_mu']}^k")

# serve: 32 concurrent clients, each sending small ragged query batches;
# the server coalesces them into a few padded device calls.
rng = np.random.default_rng(0)
queries = [rng.uniform(size=(int(b), model.input_dim)).astype(np.float32)
           for b in rng.integers(1, 24, size=32)]
latencies = []

with KernelServer(model, KernelServeConfig(max_delay_ms=5.0)) as server:
    server.predict(queries[0])  # warm the jit cache outside the timings

    def client(x):
        t0 = time.perf_counter()
        y = server.submit(x).result()
        latencies.append((time.perf_counter() - t0) * 1e3)
        assert y.shape == (x.shape[0],)

    threads = [threading.Thread(target=client, args=(q,)) for q in queries]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats = server.stats()

rows = sum(q.shape[0] for q in queries)
lat = sorted(latencies)
print(f"served {len(queries)} requests ({rows} rows) in {wall * 1e3:.1f} ms "
      f"-> {rows / wall:,.0f} rows/s")
print(f"latency p50 {lat[len(lat) // 2]:.2f} ms, p95 "
      f"{lat[int(len(lat) * 0.95)]:.2f} ms; "
      f"{stats['batches']} device calls, "
      f"{stats['mean_rows_per_batch']:.1f} rows/call "
      f"(microbatching coalesced {len(queries)} requests)")
