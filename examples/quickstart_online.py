"""Quickstart, streaming edition: decentralized ONLINE kernel learning
through `repro.api.fit_stream` — the paper's stated future-work direction,
composed with QC-ODKLA-style quantized censoring.

Six agents each receive a fresh minibatch per round from a concept-
drifting synthetic stream. The whole online family runs on identical
rounds — online_dkla (always transmit), online_coke (censored), qc_odkla
(censored + 4-bit quantized innovations, linearized-ADMM primal) — and
the fitted function deploys exactly like a batch fit: `to_model()`, then
warm-started online refinement of a batch-trained model via
`KernelModel.partial_fit`.

Run:  PYTHONPATH=src python examples/quickstart_online.py
"""
import numpy as np

from repro.api import (Censor, Chain, FitConfig, KRRConfig, Quantize,
                       build_stream, fit, fit_stream)

base = FitConfig(
    krr=KRRConfig(num_agents=6, num_features=64, lam=1e-3, rho=5e-2,
                  seed=0),
    graph="ring", stream="drift", num_iters=400, online_batch=16,
    online_lr=0.3, censor_v=None, censor_mu=None)

# One stream (per-agent minibatches, drifting target function, common-seed
# random features), shared by every streaming solver.
built = build_stream(base)
print(f"stream: {built.stream.num_rounds} rounds x "
      f"{built.stream.num_agents} agents x {built.stream.batch} samples, "
      f"kind={built.dataset.kind}")

policies = {
    "online_dkla": Chain([Censor(0.2, 0.995)]),     # censor stripped
    "online_coke": Chain([Censor(0.2, 0.995)]),
    "qc_odkla": Chain([Censor(0.2, 0.995), Quantize(bits=4)]),
}
results = {}
print(f"\n{'':14s}{'avg regret':>12s}{'# transmissions':>17s}"
      f"{'cumulative bits':>17s}")
for name, comm in policies.items():
    r = fit_stream(base.replace(algorithm=name, comm=comm),
                   stream=built.stream)
    results[name] = r
    inst = np.asarray(r.history["instant_mse"], np.float64)
    regret = inst.mean()
    print(f"{name:14s}{regret:12.3e}{int(r.comms[-1]):17d}"
          f"{int(r.bits[-1]):17,d}")

saving = 1 - float(results["qc_odkla"].bits[-1]) / float(
    results["online_dkla"].bits[-1])
print(f"\nqc_odkla pays {saving:.0%} fewer bits than the always-transmit "
      f"full-precision baseline at comparable regret\n"
      f"(benchmarks/paper_online.py draws the full regret-vs-bits curve).")

# streaming fits deploy like batch fits: the same KernelModel artifact
# (the stream was pre-built, so its RFF map is passed explicitly)
model = results["qc_odkla"].to_model(built.rff_params)
x_last = np.asarray(built.dataset.x[-1, 0])         # agent 0's last batch
preds = model.predict(x_last)
mse = float(np.mean((np.asarray(preds) - built.dataset.y[-1, 0]) ** 2))
print(f"\nKernelModel from the stream: MSE {mse:.3e} on the final round's "
      f"minibatch")

# deploy -> refine: a batch-trained model warm-starts online refinement.
# Raw inputs go in — partial_fit featurizes them with the model's OWN RFF
# map, so the refinement can never run against a foreign featurization.
batch_model = fit(base.replace(algorithm="coke", censor_v=0.2,
                               censor_mu=0.995, comm=None,
                               num_iters=300)).to_model()
refined, res = batch_model.partial_fit(
    np.asarray(built.dataset.x[:200]),
    labels=np.asarray(built.dataset.y[:200]),
    config=base.replace(algorithm="online_coke",
                        comm=Chain([Censor(0.2, 0.995)]),
                        num_iters=200))
print(f"\npartial_fit: batch-trained COKE model refined online for "
      f"{len(res.history['instant_mse'])} rounds — first-round regret "
      f"{float(res.history['instant_mse'][0]):.3e} (warm) with "
      f"{int(res.comms[-1])} transmissions; refined model serves like any "
      f"other KernelModel.")
