"""Personalized decentralized learning over clustered non-IID data.

Twenty agents draw from three latent tasks (`data.synthetic.
heterogeneous`): each cluster's labels come from its own kernel mixture,
so the strict-consensus COKE average fits none of them well. With
`FitConfig(personalization=...)` the fit alternates ADMM steps with a
graph-update step: after a warmup on the static ring, pairwise theta
affinities are re-estimated every few iterations and rewritten as a
sparse mutual-top-k adjacency, and the consensus constraint relaxes to a
similarity-weighted proximity penalty — agents keep distinct models and
collaborate only with the peers that look like them. Both arms transmit
every iteration (censor_v=0), so cumulative bits are identical and the
comparison is pure modeling.

The asserts pin the headline results: personalized beats consensus on
mean per-agent test MSE, and the learned graph's edge mass concentrates
inside the ground-truth clusters. The finale publishes all 20 per-agent
models into a `serve.ModelRegistry` — the personalization -> many-model
serving hand-off.

Run:  PYTHONPATH=src python examples/personalized.py
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import (FitConfig, KRRConfig, Personalization, build_problem,
                       fit)
from repro.core.personalize import graph_recovery
from repro.serve.registry import ModelRegistry

N, K = 20, 3

base = FitConfig(
    krr=KRRConfig(dataset="heterogeneous", num_agents=N, num_tasks=K,
                  samples_per_agent=100, num_features=64, lam=1e-3,
                  rho=0.01, censor_v=0.0, seed=0),
    graph="ring", algorithm="coke", primal="cg", num_iters=120)

built = build_problem(base)
consensus = fit(base, problem=built.problem)
personalized = fit(
    base.replace(personalization=Personalization(k=5, every=5, warmup=30)),
    problem=built.problem)

# equal bits by construction: censor_v=0 -> every agent broadcasts every
# iteration in both arms
assert np.array_equal(np.asarray(consensus.bits),
                      np.asarray(personalized.bits))


def per_agent_mse(theta):           # agent n scores its shard with theta_n
    pred = jnp.einsum("nsd,nd->ns", built.feats_test, theta)
    return np.asarray(jnp.mean((built.labels_test - pred) ** 2, axis=-1))


mse_cons = per_agent_mse(jnp.broadcast_to(jnp.mean(consensus.theta, axis=0),
                                          consensus.theta.shape))
mse_pers = per_agent_mse(personalized.theta)

print(f"{'agent':>6s}{'cluster':>9s}{'consensus':>12s}{'personalized':>14s}")
for n in range(N):
    print(f"{n:>6d}{int(built.clusters[n]):>9d}{mse_cons[n]:>12.5f}"
          f"{mse_pers[n]:>14.5f}")
print(f"\nmean per-agent test MSE: consensus {mse_cons.mean():.5f}, "
      f"personalized {mse_pers.mean():.5f} "
      f"({mse_cons.mean() / mse_pers.mean():.2f}x better at equal bits)")
assert mse_pers.mean() < mse_cons.mean()

# the learned graph found the latent clusters without being told them
A = np.asarray(personalized.learned_adjacency)
rec = float(graph_recovery(A, built.clusters))
print(f"learned graph: {int((A > 0).sum()) // 2} edges, "
      f"{100 * rec:.1f}% of edge mass intra-cluster "
      f"(chance ~{100 * (N / K - 1) / (N - 1):.0f}%)")
assert rec > 0.6

# consensus averaging would refuse: per-agent models are the artifact
try:
    personalized.to_model()
except ValueError as e:
    print(f"\nto_model() on a personalized fit: ValueError ({str(e)[:42]}...)")

with tempfile.TemporaryDirectory() as root:
    registry = ModelRegistry(root)
    published = personalized.publish_models(registry, prefix="agent",
                                            rff_params=built.rff_params)
    m7 = registry.load("agent-007")
    x = np.asarray(built.x_test[7][:4])
    print(f"published {len(published)} per-agent models; agent-007 v1 "
          f"predicts {np.asarray(m7.predict(x)).round(3)}")
    assert len(registry.models()) == N
