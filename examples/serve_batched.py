"""Batched serving example: prefill + greedy decode with KV / SSM-state
caches across three architecture families (GQA, MLA, pure-SSM).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, ServeConfig

for arch in ("qwen3-1.7b", "minicpm3-4b", "mamba2-2.7b"):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=12, cache_len=64))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 8)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    kind = {"mla": "MLA latent cache", "gqa": "GQA KV cache",
            "none": "SSM recurrent state"}[cfg.attn_kind]
    print(f"{arch:14s} [{kind:20s}] batch=4 new=12 "
          f"tok/s={4 * 12 / dt:6.1f}  first row: {out[0][:8]}")
