"""Paper Fig. 3 + Tables 3/6: communication cost to reach a target MSE,
driven entirely through `repro.api` — the whole censor grid runs as ONE
vmapped fit via `sweep()` (thresholds are traced array data), and the
no-loss operating point is picked from the per-cell trajectories.

Protocol (faithful to the paper's): censor thresholds are tuned per dataset
and per accuracy requirement — "the parameters of the censoring function are
tuned to achieve the best learning performance at nearly no performance
loss". For each MSE level we report the transmissions DKLA needs vs the best
censored run that also reaches that level (Fig. 3 reads exactly this way).

Claim validated: COKE reaches the same MSE with substantially fewer
transmissions (paper: ~45-55%; our stand-in datasets reach 35-85% depending
on the convergence-tail shape), and with a tuned schedule the final-MSE gap
is negligible.

Beyond the paper — accuracy vs cumulative BITS (the QC-ODKLA tradeoff):
with the metric moved from transmissions to bits, censoring and stochastic
4-bit innovation quantization compose (`Chain([Censor, Quantize])`), and at
equal bit budgets the quantized+censored policy dominates censor-only on
the synthetic N=20 ER(0.3) setup. The whole (v, mu, bits) grid is still
one vmapped program. `--smoke` runs a seconds-scale slice of the bits
pipeline for CI.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import (PAPER_SETUPS, Censor, Chain, FitConfig, Quantize,
                       build_problem, fit, sweep)

GRID = ((0.5, 0.98), (0.5, 0.99), (0.1, 0.995), (0.05, 0.997),
        (0.02, 0.998), (0.01, 0.999), (0.05, 0.999))

# censor schedules crossed with payload precisions for the bits curve
BITS_CENSORS = ((0.5, 0.98), (0.1, 0.995), (0.05, 0.997), (0.01, 0.999))
BITS_WIDTHS = (float("inf"), 4.0)


def comms_to_reach(mse_hist, comms_hist, target: float):
    hit = np.nonzero(np.asarray(mse_hist) <= target)[0]
    return int(np.asarray(comms_hist)[hit[0]]) if hit.size else None


def run_setup(name: str, iters: int = 1200, samples: int = 600):
    cfg = PAPER_SETUPS[name]
    base = FitConfig(algorithm="dkla", krr=cfg, num_iters=iters)
    built = build_problem(base, samples_override=samples)
    prob = built.problem
    res_d = fit(base, problem=prob)
    res_t = fit(base.replace(algorithm="cta", cta_lr=0.9), problem=prob)
    # the censor grid: one vmapped scan over traced (v, mu) thresholds
    sw = sweep(base.replace(algorithm="coke"), GRID, problem=prob)
    coke_mse = np.asarray(sw.history["train_mse"])   # (G, iters)
    coke_comms = np.asarray(sw.history["comms"])     # (G, iters)

    final = float(res_d.train_mse[-1])
    first = float(res_d.train_mse[0])
    rows = []
    for frac in (0.1, 0.01, 0.003):
        tgt = final + (first - final) * frac
        cd = comms_to_reach(res_d.train_mse, res_d.comms, tgt)
        best = None
        for gi, (v, mu) in enumerate(GRID):
            cc = comms_to_reach(coke_mse[gi], coke_comms[gi], tgt)
            if cc is not None and (best is None or cc < best[0]):
                best = (cc, v, mu)
        rows.append({
            "dataset": name, "target_mse": tgt,
            "cta": comms_to_reach(res_t.train_mse, res_t.comms, tgt),
            "dkla": cd,
            "coke": best[0] if best else None,
            "coke_schedule": f"{best[1]}*{best[2]}^k" if best else None,
            "saving": (1 - best[0] / cd) if (best and cd) else None,
        })

    # no-loss summary: best total saving among cells with <=1% final-MSE gap
    no_loss = [(1 - int(coke_comms[gi, -1]) / int(res_d.comms[-1]), v, mu)
               for gi, (v, mu) in enumerate(GRID)
               if (float(coke_mse[gi, -1]) - final) / max(final, 1e-12)
               <= 0.01]
    no_loss.sort(reverse=True)
    summary = {"no_loss_saving": no_loss[0][0] if no_loss else 0.0,
               "no_loss_schedule": (f"{no_loss[0][1]}*{no_loss[0][2]}^k"
                                    if no_loss else "dkla")}
    return rows, summary


def mse_at_budget(mse_hist, bits_hist, budget: float):
    """Best MSE reachable having paid <= budget cumulative bits."""
    ok = np.nonzero(np.asarray(bits_hist) <= budget)[0]
    return float(np.min(np.asarray(mse_hist)[ok])) if ok.size else None


def run_bits_curve(name: str = "synthetic", iters: int = 1200,
                   samples: int = 600, censors=BITS_CENSORS,
                   widths=BITS_WIDTHS, points: int = 12):
    """Accuracy vs cumulative bits — the QC-ODKLA-style tradeoff. The full
    (v, mu) x bits grid is ONE vmapped sweep over stacked
    Chain([Censor, Quantize]) policies; each curve point reports, per
    payload width, the best training MSE any schedule reaches within the
    bit budget."""
    cfg = PAPER_SETUPS[name]
    base = FitConfig(algorithm="coke", krr=cfg, num_iters=iters,
                     censor_v=None, censor_mu=None)
    built = build_problem(base, samples_override=samples)
    cells = [Chain([Censor(v, mu), Quantize(bits=b)])
             for b in widths for (v, mu) in censors]
    labels = [f"b{'inf' if np.isinf(b) else int(b)}"
              for b in widths for _ in censors]
    sw = sweep(base, cells, problem=built.problem)
    mse = np.asarray(sw.history["train_mse"])     # (G, iters)
    bits = np.asarray(sw.history["bits"])         # (G, iters)

    lo = float(bits[:, 0].min())
    hi = float(bits[:, -1].max())
    budgets = np.logspace(np.log10(max(lo, 1.0)), np.log10(hi), points)
    curve = []
    for budget in budgets:
        row = {"budget_bits": float(budget)}
        for b in widths:
            key = f"b{'inf' if np.isinf(b) else int(b)}"
            per_cell = [mse_at_budget(mse[gi], bits[gi], budget)
                        for gi in range(len(cells)) if labels[gi] == key]
            reached = [m for m in per_cell if m is not None]
            row[key] = min(reached) if reached else None
        curve.append(row)
    return curve


def emit_bits_curve(emit, name: str = "synthetic", **kw):
    curve = run_bits_curve(name, **kw)
    keys = [k for k in curve[0] if k != "budget_bits"]
    wins = 0
    comparable = 0
    for row in curve:
        cells = ";".join(
            f"{k}={row[k]:.3e}" if row[k] is not None else f"{k}=na"
            for k in keys)
        emit(f"paper_comm_cost/{name}/bits{row['budget_bits']:.3e}", 0.0,
             cells)
        if len(keys) >= 2 and all(row[k] is not None for k in keys):
            comparable += 1
            if row[keys[-1]] <= row[keys[0]]:   # low-bit vs full-precision
                wins += 1
    if comparable:
        emit(f"paper_comm_cost/{name}/bits_claim", 0.0,
             f"q{keys[-1]}_beats_{keys[0]}_at_equal_budget="
             f"{wins}/{comparable}")
    return curve


def main(emit, smoke: bool = False):
    if smoke:
        # CI slice: exercise the (v, mu, bits) sweep + bits accounting on
        # a seconds-scale synthetic problem and sanity-check the curve
        curve = emit_bits_curve(emit, "synthetic", iters=150, samples=60,
                                censors=((0.5, 0.98), (0.05, 0.997)),
                                points=6)
        assert any(row["b4"] is not None for row in curve), \
            "bits accounting produced no reachable 4-bit curve points"
        return
    iters_by = {"synthetic": 2000}
    for name in ("synthetic", "toms_hardware", "energy", "air_quality"):
        rows, s = run_setup(name, iters=iters_by.get(name, 1200))
        for r in rows:
            sv = f"{r['saving']:.0%}" if r["saving"] is not None else "na"
            emit(f"paper_comm_cost/{name}/mse{r['target_mse']:.3e}", 0.0,
                 f"cta={r['cta']};dkla={r['dkla']};coke={r['coke']}"
                 f";saving={sv};h(k)={r['coke_schedule']}")
        emit(f"paper_comm_cost/{name}/no_loss", 0.0,
             f"saving={s['no_loss_saving']:.2%};"
             f"h(k)={s['no_loss_schedule']}")
    emit_bits_curve(emit, "synthetic", iters=iters_by["synthetic"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice of the bits pipeline")
    args = ap.parse_args()
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"), smoke=args.smoke)
