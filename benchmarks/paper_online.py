"""The streaming workload's tradeoff curve: regret vs cumulative bits —
the QC-ODKLA (Xu et al., 2022) reading of COKE's future-work direction,
driven entirely through `repro.api.fit_stream`.

Protocol: one synthetic per-agent minibatch stream (stationary by default;
`--stream drift/shift` exercises the non-stationary generators), the whole
online family on identical rounds:

  online_dkla — always transmit, full precision (the online baseline),
  online_coke — censored transmissions, h(k) = v mu^k,
  qc_odkla    — linearized ADMM with Censor + stochastic 4-bit innovation
                quantization (the QC-ODKLA-shaped operating point).

For each solver the per-round average regret (running mean of the
pre-update instantaneous MSE — the standard online-learning metric) is
reported against the cumulative bits the network has paid by that round.
The QC-ODKLA-shaped claim: at every equal bit budget the censored+
quantized policy attains at-most the regret of the uncensored
full-precision baseline, i.e. its curve dominates.

`--smoke` runs a seconds-scale slice for CI and asserts the claim on the
final budget: qc_odkla reaches within 1.2x of online_dkla's final average
regret while paying under half the bits.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import (Censor, Chain, FitConfig, KRRConfig, Quantize,
                       build_stream, fit_stream)

SOLVERS = ("online_dkla", "online_coke", "qc_odkla")


def _policy(name: str, v: float, mu: float, bits: float):
    if name == "online_dkla":
        # censor stage present but structurally stripped by the solver —
        # keeps the chain shape comparable across rows
        return Chain([Censor(v, mu)])
    if name == "online_coke":
        return Chain([Censor(v, mu)])
    return Chain([Censor(v, mu), Quantize(bits=bits)])


def run_curve(kind: str = "stationary", rounds: int = 1200,
              num_agents: int = 10, batch: int = 8, features: int = 64,
              v: float = 0.2, mu: float = 0.995, bits: float = 4.0,
              lr: float = 0.3, points: int = 12):
    """-> (budgets, {solver: regret-at-budget}) plus the per-solver finals."""
    base = FitConfig(
        krr=KRRConfig(num_agents=num_agents, num_features=features,
                      lam=1e-3, rho=5e-2, seed=0),
        censor_v=None, censor_mu=None, num_iters=rounds,
        online_batch=batch, online_lr=lr, stream=kind)
    built = build_stream(base)
    runs = {}
    for name in SOLVERS:
        r = fit_stream(base.replace(algorithm=name,
                                    comm=_policy(name, v, mu, bits)),
                       stream=built.stream)
        inst = np.asarray(r.history["instant_mse"], np.float64)
        regret = np.cumsum(inst) / np.arange(1, rounds + 1)
        runs[name] = {"regret": regret,
                      "bits": np.asarray(r.history["bits"], np.float64),
                      "comms": np.asarray(r.history["comms"], np.int64)}

    hi = max(r["bits"][-1] for r in runs.values())
    lo = max(min(r["bits"][r["bits"] > 0][0] if (r["bits"] > 0).any()
                 else hi for r in runs.values()), 1.0)
    budgets = np.logspace(np.log10(lo), np.log10(hi), points)
    curve = []
    for budget in budgets:
        row = {"budget_bits": float(budget)}
        for name, r in runs.items():
            ok = np.nonzero(r["bits"] <= budget)[0]
            row[name] = float(r["regret"][ok[-1]]) if ok.size else None
        curve.append(row)
    return curve, runs


def main(emit, smoke: bool = False, kind: str = "stationary"):
    kw = dict(rounds=200, num_agents=6, batch=8, features=32,
              points=6) if smoke else {}
    curve, runs = run_curve(kind=kind, **kw)
    for row in curve:
        cells = ";".join(
            f"{n}={row[n]:.3e}" if row[n] is not None else f"{n}=na"
            for n in SOLVERS)
        emit(f"paper_online/{kind}/bits{row['budget_bits']:.3e}", 0.0,
             cells)
    finals = {n: (runs[n]["regret"][-1], runs[n]["bits"][-1],
                  int(runs[n]["comms"][-1])) for n in SOLVERS}
    for n, (reg, bits, comms) in finals.items():
        emit(f"paper_online/{kind}/{n}/final", 0.0,
             f"regret={reg:.3e};bits={bits:.3e};comms={comms}")
    if smoke:
        reg_d, bits_d, _ = finals["online_dkla"]
        reg_q, bits_q, _ = finals["qc_odkla"]
        assert bits_q < 0.5 * bits_d, \
            f"qc_odkla paid {bits_q:.3e} bits vs dkla's {bits_d:.3e}"
        assert reg_q < 1.2 * reg_d, \
            f"qc_odkla regret {reg_q:.3e} vs dkla's {reg_d:.3e}"
        # censoring engaged: online_coke transmitted strictly less
        assert finals["online_coke"][2] < finals["online_dkla"][2]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI slice with the claim asserted")
    ap.add_argument("--stream", default="stationary",
                    choices=("stationary", "drift", "shift"))
    args = ap.parse_args()
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"), smoke=args.smoke,
         kind=args.stream)
