"""Roofline table from the cached dry-run artifacts (§Roofline deliverable).

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and emits the
per-(arch x shape x mesh) three-term roofline with the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS useful fraction, and per-device memory. Also writes a
markdown table to results/roofline.md for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_results(pattern: str = "*.json") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, pattern))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def _fmt(x):
    return f"{x:.2e}" if isinstance(x, float) else str(x)


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | strat | compute_s | memory_s | "
           "collective_s | dominant | useful | peak_GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | "
                         f"{r.get('mesh_kind')} | {r.get('strategy','-')} |"
                         f" — | — | — | {r.get('status')} | — | — |")
            continue
        roof = r["roofline"]
        peak = r["memory"].get("temp_bytes") or 0
        arg = r["memory"].get("argument_bytes") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh_kind']} | "
            f"{r.get('strategy','allreduce')}{'+fsdp' if r.get('fsdp') else ''} | "
            f"{roof['compute_s']:.2e} | {roof['memory_s']:.2e} | "
            f"{roof['collective_s']:.2e} | {roof['dominant']} | "
            f"{roof['useful_fraction']:.2f} | "
            f"{(peak + arg) / 1e9:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main(emit):
    rows = load_results()
    base = [r for r in rows if r.get("strategy", "allreduce") == "allreduce"
            and not r.get("fsdp")]
    ok = [r for r in base if r.get("status") == "ok"]
    for r in ok:
        roof = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh_kind']}",
             roof["step_s_lower_bound"] * 1e6,
             f"dom={roof['dominant']};useful={roof['useful_fraction']:.2f};"
             f"coll_GB={roof['collective_bytes_per_device'] / 1e9:.2f}")
    emit("roofline/summary", 0.0,
         f"ok={len(ok)};skipped={sum(1 for r in base if r.get('status') == 'skipped')};"
         f"errors={sum(1 for r in base if r.get('status') == 'error')}")
    md = markdown_table(rows)
    out = os.path.join(RESULTS_DIR, "..", "roofline.md")
    with open(out, "w") as f:
        f.write(md)


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
