"""Shared benchmark plumbing, written against the `repro.api` surface.

`build_problem` keeps its historical tuple signature for the benchmark
scripts but delegates construction to `repro.api.build_problem`;
`tune_censor` sweeps censor schedules through `fit()` — the thresholds are
traced, so the whole grid reuses one compiled fit loop.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import FitConfig, KRRConfig, fit
from repro.api import build_problem as api_build_problem


def build_problem(cfg: KRRConfig, samples_override: int | None = None):
    """-> (problem, graph, rffparams, (feats_test, labels_test))."""
    built = api_build_problem(cfg, samples_override=samples_override)
    return (built.problem, built.graph, built.rff_params,
            (built.feats_test, built.labels_test))


def test_mse(theta_stack, feats_test, labels_test) -> float:
    """Per-agent test MSE from precomputed features. New code should prefer
    `FitResult.to_model().evaluate(x_test, y_test)` — same numbers from raw
    inputs (parity pinned in tests/test_model.py)."""
    preds = jnp.einsum("ntd,nd->nt", feats_test, theta_stack)
    return float(jnp.mean((labels_test - preds) ** 2))


def tune_censor(prob, iters: int = 600, max_gap: float = 0.01,
                grid=((0.5, 0.98), (0.5, 0.99), (0.1, 0.995), (0.05, 0.997),
                      (0.02, 0.998), (0.01, 0.998), (0.005, 0.999))):
    """Per-dataset censor-threshold tuning, mirroring the paper's protocol
    ("parameters ... tuned to achieve the best learning performance at
    nearly no performance loss"): pick the (v, mu) with the largest
    communication saving whose final-MSE gap vs DKLA is <= max_gap.
    Returns (best FitConfig, saving)."""
    base = FitConfig(algorithm="dkla", num_iters=iters)
    res_d = fit(base, problem=prob)
    final_d = float(res_d.train_mse[-1])
    best = (0.0, base)  # (saving, config): fallback = DKLA
    for v, mu in grid:
        cfg = base.replace(algorithm="coke", censor_v=v, censor_mu=mu)
        r = fit(cfg, problem=prob)
        gap = (float(r.train_mse[-1]) - final_d) / max(final_d, 1e-12)
        saving = 1.0 - int(r.comms[-1]) / max(int(res_d.comms[-1]), 1)
        if gap <= max_gap and saving > best[0]:
            best = (saving, cfg)
    return best[1], best[0]


def time_call(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
