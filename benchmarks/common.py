"""Shared benchmark plumbing: problem construction + timing helpers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.coke_krr import KRRConfig
from repro.core import admm, graph, rff
from repro.data.synthetic import paper_synthetic, uci_standin


def build_problem(cfg: KRRConfig, samples_override: int | None = None):
    """-> (problem, graph, rffparams, dataset) for a paper setup."""
    n = samples_override or cfg.samples_per_agent
    if cfg.dataset == "synthetic":
        ds = paper_synthetic(num_agents=cfg.num_agents, samples_per_agent=n,
                            seed=cfg.seed)
        g = graph.erdos_renyi(cfg.num_agents, cfg.graph_p, seed=cfg.seed)
    else:
        ds = uci_standin(cfg.dataset, num_agents=cfg.num_agents,
                         subsample=n * cfg.num_agents)
        g = graph.erdos_renyi(cfg.num_agents, cfg.graph_p, seed=cfg.seed + 1)
    p = rff.draw_rff(jax.random.PRNGKey(cfg.seed), ds.input_dim,
                     cfg.num_features, cfg.bandwidth, mapping=cfg.mapping)
    feats = rff.featurize(p, jnp.asarray(ds.x))
    labels = jnp.asarray(ds.y)
    prob = admm.make_problem(feats, labels, g, lam=cfg.lam, rho=cfg.rho)
    feats_test = rff.featurize(p, jnp.asarray(ds.x_test))
    labels_test = jnp.asarray(ds.y_test)
    return prob, g, p, (feats_test, labels_test)


def test_mse(theta_stack, feats_test, labels_test) -> float:
    preds = jnp.einsum("ntd,nd->nt", feats_test, theta_stack)
    return float(jnp.mean((labels_test - preds) ** 2))


def tune_censor(prob, iters: int = 600, max_gap: float = 0.01,
                grid=((0.5, 0.98), (0.5, 0.99), (0.1, 0.995), (0.05, 0.997),
                      (0.02, 0.998), (0.01, 0.998), (0.005, 0.999))):
    """Per-dataset censor-threshold tuning, mirroring the paper's protocol
    ("parameters ... tuned to achieve the best learning performance at
    nearly no performance loss"): pick the (v, mu) with the largest
    communication saving whose final-MSE gap vs DKLA is <= max_gap."""
    from repro.core.censor import CensorSchedule
    res_d = admm.run(prob, admm.dkla_schedule(), iters)
    final_d = float(res_d.train_mse[-1])
    best = (0.0, 0.0, 0.5)  # (saving, v, mu): fallback = DKLA (v=0)
    for v, mu in grid:
        r = admm.run(prob, CensorSchedule(v, mu), iters)
        gap = (float(r.train_mse[-1]) - final_d) / max(final_d, 1e-12)
        saving = 1.0 - int(r.comms[-1]) / max(int(res_d.comms[-1]), 1)
        if gap <= max_gap and saving > best[0]:
            best = (saving, v, mu)
    return CensorSchedule(best[1], best[2]), best[0]


def time_call(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
