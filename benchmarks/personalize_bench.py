"""Personalized-vs-consensus on clustered non-IID data at EQUAL bits.

The acceptance experiment for the personalization subsystem: N=20 agents
over K=3 latent tasks (`data.synthetic.heterogeneous`), censor_v=0 so
both arms transmit every iteration — cumulative bits are bit-identical
by construction (asserted) and any per-agent test-MSE gap is purely the
learned collaboration graph vs strict consensus. Two row families:

    personalize/consensus/N20      static-ring COKE, consensus-averaged
    personalize/personalized/N20   learned mutual-top-k graph, per-agent

`us_per_call` is the best-of-N latency of the jitted per-iteration step
(static coke_step vs refresh+dense-proximity step), so the perf gate
compares like against like; derived fields carry mean per-agent test MSE,
cumulative bits, and the graph-recovery score (intra-cluster edge-mass
fraction vs the generator's ground-truth clusters). The run FAILS — no
silent rows — unless personalized beats consensus and bits match.
--smoke shrinks iteration counts but keeps the SAME N, so CI smoke rows
match the committed BENCH_personalize.json baseline by name.

    python -m benchmarks.personalize_bench            # full
    python -m benchmarks.personalize_bench --smoke    # CI
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.gossip_bench import time_min
from repro.api import (FitConfig, KRRConfig, Personalization, build_problem,
                       fit)
from repro.core import admm
from repro.core import personalize as P

NUM_AGENTS = 20
NUM_TASKS = 3
PZ = Personalization(k=5, every=5, warmup=30)

KRR = KRRConfig(dataset="heterogeneous", num_agents=NUM_AGENTS,
                samples_per_agent=100, num_tasks=NUM_TASKS,
                num_features=64, lam=1e-3, rho=0.01,
                censor_v=0.0, censor_mu=0.97, seed=0)


def _per_agent_test_mse(built, theta) -> float:
    pred = jnp.einsum("nsd,nd->ns", built.feats_test, theta)
    return float(jnp.mean((built.labels_test - pred) ** 2))


def _step_latencies(built, policy, timing_iters: int) -> tuple[float, float]:
    """Best-of-N us/call of the static step vs the personalized live step
    (graph-refresh cond + dense proximity update), both jitted."""
    problem = built.problem
    state0 = admm.init_state(problem, policy=policy)

    def static_step(problem, state):
        return admm.coke_step(problem, policy, state, None, primal="cg")

    pz_state0 = P.PersonalizedState(
        state0, jnp.asarray(problem.adjacency, jnp.float32))

    def pz_step(problem, state):
        A = P.maybe_update(PZ, state.inner.theta, state.inner.step + 1,
                           state.adjacency)
        inner = admm.coke_step(dataclasses.replace(problem, adjacency=A),
                               policy, state.inner, None, primal="cg")
        return P.PersonalizedState(inner, A)

    us_static = time_min(jax.jit(static_step), problem, state0,
                         iters=timing_iters)
    us_pz = time_min(jax.jit(pz_step), problem, pz_state0,
                     iters=timing_iters)
    return us_static, us_pz


def main(emit, smoke: bool = False) -> dict:
    num_iters = 80 if smoke else 300
    timing_iters = 20 if smoke else 50
    cfg = FitConfig(krr=KRR, graph="ring", num_iters=num_iters, primal="cg")
    built = build_problem(cfg)

    cons = fit(cfg, problem=built.problem)
    pers = fit(cfg.replace(personalization=PZ), problem=built.problem)

    # the equal-bits contract: censor_v=0 means both arms broadcast every
    # iteration — if this ever drifts the comparison is meaningless
    if not np.array_equal(np.asarray(cons.history["bits"]),
                          np.asarray(pers.history["bits"])):
        raise AssertionError("bit trajectories differ — the equal-bits "
                            "protocol is broken")

    mse_cons = _per_agent_test_mse(built, jnp.broadcast_to(
        jnp.mean(cons.theta, axis=0), cons.theta.shape))
    mse_pers = _per_agent_test_mse(built, pers.theta)
    recovery = float(P.graph_recovery(pers.learned_adjacency,
                                      built.clusters))
    if not mse_pers < mse_cons:
        raise AssertionError(
            f"personalized ({mse_pers:.5f}) did not beat consensus "
            f"({mse_cons:.5f}) on mean per-agent test MSE")

    bits = int(cons.history["bits"][-1])
    us_static, us_pz = _step_latencies(built, cfg.resolved_comm,
                                       timing_iters)
    emit(f"personalize/consensus/N{NUM_AGENTS}", us_static,
         f"per_agent_test_mse={mse_cons:.5f};bits={bits};"
         f"iters={num_iters}")
    emit(f"personalize/personalized/N{NUM_AGENTS}", us_pz,
         f"per_agent_test_mse={mse_pers:.5f};bits={bits};"
         f"iters={num_iters};recovery={recovery:.3f};"
         f"k={PZ.k};every={PZ.every};warmup={PZ.warmup}")
    return {"mse_consensus": mse_cons, "mse_personalized": mse_pers,
            "recovery": recovery, "bits": bits}


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"),
         smoke="--smoke" in sys.argv[1:])
