"""The perf-regression gate: fail CI when a fresh BENCH json regresses.

Compares per-row `us_per_call` of a fresh `benchmarks.run --json` record
against a committed baseline, matched BY ROW NAME. A row fails when

    fresh_us > PERF_GATE_FACTOR * baseline_us        (default factor 1.5)

Rows named `total_wall_s` or `*/ERROR` and rows present on only one side
are reported but never gated (suite composition may drift between the
baseline and a smoke run; an ERROR row should fail its own CI step, not
masquerade as a latency regression). The baseline's git sha + timestamp
stamps (benchmarks/run.py) are echoed so a gate failure names the exact
commit it regressed against.

Committed baselines at the repo root: `BENCH_gossip.json` (agent-axis
scaling), `BENCH_many_model.json` (multi-tenant serving), and
`BENCH_personalize.json` (personalized vs consensus on clustered
non-IID data) — CI runs the matching suite with --smoke and gates each
fresh record against its baseline.

    python -m benchmarks.perf_gate BENCH_fresh.json BENCH_gossip.json
    PERF_GATE_FACTOR=2.0 python -m benchmarks.perf_gate fresh.json base.json
"""
from __future__ import annotations

import json
import os
import sys


def _rows(record: dict) -> tuple[dict[str, float], dict[str, str]]:
    """-> (gateable rows, malformed rows as name -> reason).

    A row missing `us_per_call` or with a non-positive value cannot
    anchor a ratio (a <= 0 baseline would make every fresh value an
    "infinite regression"); such rows are reported as malformed / not
    gated instead of raising or spuriously failing."""
    out: dict[str, float] = {}
    bad: dict[str, str] = {}
    for row in record.get("results", []):
        name = row.get("name", "")
        if name == "total_wall_s" or name.endswith("/ERROR"):
            continue
        if "us_per_call" not in row:
            bad[name] = "missing us_per_call"
            continue
        try:
            us = float(row["us_per_call"])
        except (TypeError, ValueError):
            bad[name] = f"non-numeric us_per_call {row['us_per_call']!r}"
            continue
        if not us > 0:
            bad[name] = f"non-positive us_per_call {us!r}"
            continue
        out[name] = us
    return out, bad


def gate(fresh: dict, baseline: dict, factor: float) -> list[str]:
    """-> list of human-readable failures (empty = gate green)."""
    f_rows, f_bad = _rows(fresh)
    b_rows, b_bad = _rows(baseline)
    failures = []
    for name in sorted(f_rows.keys() & b_rows.keys()):
        new, old = f_rows[name], b_rows[name]
        ratio = new / old
        status = "FAIL" if ratio > factor else "ok"
        print(f"{status:>4}  {name:<40} {old:>12.1f} -> {new:>12.1f} us  "
              f"({ratio:.2f}x, limit {factor:.2f}x)")
        if status == "FAIL":
            failures.append(f"{name}: {old:.1f} -> {new:.1f} us "
                            f"({ratio:.2f}x > {factor:.2f}x)")
    for name in sorted(f_rows.keys() - b_rows.keys()):
        print(f"  new  {name} (no baseline row — not gated)")
    for name in sorted(b_rows.keys() - f_rows.keys()):
        print(f"  gone {name} (baseline-only row — not gated)")
    for name, reason in sorted(f_bad.items()):
        print(f"  WARN fresh row {name} malformed ({reason}) — not gated")
    for name, reason in sorted(b_bad.items()):
        print(f"  WARN baseline row {name} malformed ({reason}) "
              "— not gated")
    if not (f_rows.keys() & b_rows.keys()):
        failures.append("no rows in common between fresh and baseline — "
                        "the gate compared nothing")
    return failures


def summary_table(fresh: dict, baseline: dict, factor: float,
                  baseline_name: str) -> str:
    """The gate comparison as a GitHub-flavored markdown table — what CI
    appends to $GITHUB_STEP_SUMMARY so a reviewer reads the latency deltas
    on the run page instead of scrolling raw logs."""
    f_rows, f_bad = _rows(fresh)
    b_rows, b_bad = _rows(baseline)
    lines = [
        f"### perf gate: `{baseline_name}` "
        f"(sha `{baseline.get('git_sha')}`, limit {factor:.2f}x)",
        "",
        "| row | baseline (us) | fresh (us) | ratio | status |",
        "|---|---:|---:|---:|---|",
    ]
    for name in sorted(f_rows.keys() & b_rows.keys()):
        new, old = f_rows[name], b_rows[name]
        ratio = new / old
        status = "❌ FAIL" if ratio > factor else "✅ ok"
        lines.append(f"| `{name}` | {old:.1f} | {new:.1f} "
                     f"| {ratio:.2f}x | {status} |")
    for name in sorted(f_rows.keys() - b_rows.keys()):
        lines.append(f"| `{name}` | — | {f_rows[name]:.1f} | — "
                     "| 🆕 not gated |")
    for name in sorted(b_rows.keys() - f_rows.keys()):
        lines.append(f"| `{name}` | {b_rows[name]:.1f} | — | — "
                     "| gone, not gated |")
    for name, reason in sorted({**b_bad, **f_bad}.items()):
        lines.append(f"| `{name}` | — | — | — "
                     f"| ⚠️ malformed ({reason}), not gated |")
    return "\n".join(lines) + "\n"


def _write_step_summary(fresh: dict, baseline: dict, factor: float,
                        baseline_path: str) -> None:
    """Append the markdown comparison to $GITHUB_STEP_SUMMARY when CI set
    it (each gated baseline appends its own section); no-op locally."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        f.write(summary_table(fresh, baseline, factor,
                              os.path.basename(baseline_path)))
        f.write("\n")


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print(__doc__)
        return 2
    fresh_path, baseline_path = argv
    factor = float(os.environ.get("PERF_GATE_FACTOR", "1.5"))
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    print(f"baseline: {baseline_path} "
          f"(sha={baseline.get('git_sha')}, "
          f"recorded={baseline.get('timestamp')})")
    failures = gate(fresh, baseline, factor)
    _write_step_summary(fresh, baseline, factor, baseline_path)
    if failures:
        print("\nperf gate FAILED:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("\nperf gate green")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
