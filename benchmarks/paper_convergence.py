"""Paper Figs. 1-2: functional consensus + training-MSE convergence of
CTA / DKLA / COKE on the synthetic and a real-protocol dataset, driven
entirely through `repro.api.fit`.

Claims validated:
  * every agent's functional converges to the centralized optimum (Fig 1),
  * ADMM-based (DKLA, COKE) converge faster than diffusion CTA (Fig 2),
  * COKE matches DKLA's final MSE despite censored transmissions.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import build_problem, test_mse, tune_censor
from repro.api import PAPER_SETUPS, FitConfig, fit, rf_ridge


def run_setup(name: str, iters: int = 600, samples: int = 400,
              checkpoints=(50, 100, 200, 400, 600)) -> list[dict]:
    cfg = PAPER_SETUPS[name]
    prob, g, _, (ft, lt) = build_problem(cfg, samples_override=samples)
    theta_star = rf_ridge(prob.feats, prob.labels, cfg.lam)
    mse_star = float(jnp.mean(
        (prob.labels - jnp.einsum("ntd,d->nt", prob.feats, theta_star)) ** 2))

    coke_cfg, _ = tune_censor(prob, iters=iters)
    base = FitConfig(algorithm="dkla", num_iters=iters)
    res_d = fit(base, problem=prob)
    res_c = fit(coke_cfg.replace(num_iters=iters), problem=prob)
    res_t = fit(base.replace(algorithm="cta", cta_lr=0.9), problem=prob)

    rows = []
    for k in checkpoints:
        if k > iters:
            continue
        i = k - 1
        rows.append({
            "dataset": name, "iteration": k, "mse_star": mse_star,
            "cta_mse": float(res_t.train_mse[i]),
            "dkla_mse": float(res_d.train_mse[i]),
            "coke_mse": float(res_c.train_mse[i]),
            "cta_comms": int(res_t.comms[i]),
            "dkla_comms": int(res_d.comms[i]),
            "coke_comms": int(res_c.comms[i]),
            "coke_bits": int(res_c.bits[i]),
            "dkla_bits": int(res_d.bits[i]),
            "coke_consensus_gap": float(res_c.consensus_gap[i]),
            "coke_dist_to_star": res_c.distance_to(theta_star),
            "coke_test_mse": test_mse(res_c.theta, ft, lt),
            "dkla_test_mse": test_mse(res_d.theta, ft, lt),
        })
    return rows


def main(emit):
    for name in ("synthetic", "twitter_large"):
        rows = run_setup(name)
        last = rows[-1]
        # paper claims, asserted softly as derived metrics:
        admm_beats_cta = last["dkla_mse"] <= last["cta_mse"] + 1e-9
        coke_matches = abs(last["coke_mse"] - last["dkla_mse"]) \
            / max(last["dkla_mse"], 1e-12) < 0.05
        saving = 1.0 - last["coke_comms"] / max(last["dkla_comms"], 1)
        for r in rows:
            emit(f"paper_convergence/{name}/k{r['iteration']}", 0.0,
                 f"cta={r['cta_mse']:.3e};dkla={r['dkla_mse']:.3e};"
                 f"coke={r['coke_mse']:.3e};comms={r['coke_comms']};"
                 f"bits={r['coke_bits']}")
        emit(f"paper_convergence/{name}/claims", 0.0,
             f"admm_beats_cta={admm_beats_cta};coke_matches_dkla={coke_matches};"
             f"comm_saving={saving:.2%};gap={last['coke_consensus_gap']:.2e}")


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
