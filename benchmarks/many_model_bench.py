"""Many-model serving benchmark: models-resident × QPS × p99 latency.

The question this answers: with one `KernelServer` holding M per-user
thetas resident in a single (M, D) `ThetaStore` stack, what request
throughput and tail latency does the multi-tenant gathered scorer sustain
— and what does paging cost when the working set overflows the store?

Scenarios per M:
  - resident: store capacity >= M, every model preloaded — the pure
    gather-scoring ceiling (no faults).
  - paged:    store capacity = M // 4 against a disk registry, uniform
    traffic — every flush faults; measures the paging penalty.

Run:  PYTHONPATH=src python -m benchmarks.many_model_bench [--smoke] [--json F]
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
import threading
import time

import numpy as np

from repro.api import FitConfig, KRRConfig, fit
from repro.serve import (KernelServeConfig, KernelServer, ModelRegistry,
                         ThetaStore)


def _base_model(D: int = 128):
    cfg = FitConfig(
        krr=KRRConfig(num_agents=4, samples_per_agent=50, num_features=D,
                      lam=1e-3, rho=5e-2, seed=0),
        algorithm="coke", censor_v=0.1, censor_mu=0.995, num_iters=50)
    return fit(cfg).to_model()


def _variant_thetas(base, M: int) -> np.ndarray:
    rng = np.random.default_rng(42)
    return (np.asarray(base.theta)[None, :]
            + rng.normal(scale=0.1, size=(M, base.num_features))
            ).astype(np.float32)


def _drive(server: KernelServer, ids: list[str], *, clients: int,
           requests_per_client: int, batch: int, seed: int = 0) -> dict:
    """Closed-loop load: `clients` threads, each firing tagged requests
    back-to-back. Returns QPS / latency percentiles."""
    input_dim = server.model.input_dim
    latencies: list[float] = []
    lock = threading.Lock()

    def client(cid: int):
        rng = np.random.default_rng(seed + cid)
        mine = []
        for _ in range(requests_per_client):
            mid = ids[int(rng.integers(0, len(ids)))]
            x = rng.uniform(size=(batch, input_dim)).astype(np.float32)
            t0 = time.perf_counter()
            server.submit(x, mid).result()
            mine.append((time.perf_counter() - t0) * 1e3)
        with lock:
            latencies.extend(mine)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lat = np.sort(np.asarray(latencies))
    n = len(lat)
    stats = server.stats()
    return {
        "requests": n,
        "qps": n / wall,
        "rows_per_s": n * batch / wall,
        "p50_ms": float(lat[n // 2]),
        "p99_ms": float(lat[min(n - 1, int(n * 0.99))]),
        "batches": stats["batches"],
        "faults": stats.get("store", {}).get("faults", 0),
        "evictions": stats.get("store", {}).get("evictions", 0),
    }


def run(models_resident=(100, 1000), *, D: int = 128, clients: int = 8,
        requests_per_client: int = 40, batch: int = 4,
        smoke: bool = False) -> dict:
    if smoke:
        # keep M=100 so the smoke rows (resident/M100, paged/M100) match
        # the committed BENCH_many_model.json baseline BY NAME and the
        # perf gate has rows to compare
        models_resident, clients, requests_per_client = (100,), 4, 10
    base = _base_model(D)
    cfg = KernelServeConfig(max_delay_ms=1.0)
    out: dict[str, dict] = {}
    for M in models_resident:
        ids = [f"u{i:06d}" for i in range(M)]
        thetas = _variant_thetas(base, M)

        # resident: everything preloaded, capacity >= M (+1 slot for the
        # server's default/template model)
        store = ThetaStore(M + 1, base.num_features)
        store.put_many(ids, thetas)
        with KernelServer(model=base, store=store, config=cfg) as server:
            server.predict(np.zeros((batch, base.input_dim), np.float32),
                           ids[0])  # warm the jit cache outside timings
            res = _drive(server, ids, clients=clients,
                         requests_per_client=requests_per_client,
                         batch=batch)
            res["resident"] = len(store)
            out[f"resident/M{M}"] = res

        # paged: capacity M//4 over a disk registry — uniform traffic
        # faults constantly; this is the worst-case paging penalty
        with tempfile.TemporaryDirectory() as root:
            reg = ModelRegistry(root)
            for mid, theta in zip(ids, thetas):
                reg.publish(mid, dataclasses.replace(
                    base, theta=theta, thetas=None))
            with KernelServer(model=base, registry=reg,
                              store_capacity=max(2, M // 4),
                              config=cfg) as server:
                server.predict(np.zeros((batch, base.input_dim), np.float32),
                               ids[0])
                res = _drive(server, ids, clients=clients,
                             requests_per_client=requests_per_client,
                             batch=batch, seed=100)
                res["resident"] = max(2, M // 4)
                out[f"paged/M{M}"] = res

        if smoke:
            # correctness spot check riding along: a tagged answer must be
            # bit-identical to the row-wise reference for its theta
            store = ThetaStore(M + 1, base.num_features)
            store.put_many(ids, thetas)
            with KernelServer(model=base, store=store, config=cfg) as srv:
                rng = np.random.default_rng(0)
                x = rng.uniform(size=(4, base.input_dim)).astype(np.float32)
                got = np.asarray(srv.predict(x, ids[3]))
                import jax.numpy as jnp
                ref = np.asarray(base.score_rows(
                    x, jnp.broadcast_to(jnp.asarray(thetas[3]),
                                        (4, base.num_features))))
                assert np.array_equal(got, ref), \
                    "smoke: served answer != row-wise reference"
    return out


def main(emit, smoke: bool = False) -> dict:
    rows = run(smoke=smoke)
    for name, r in rows.items():
        emit(f"many_model/{name}", r["p99_ms"] * 1e3,
             f"qps={r['qps']:.0f};p50_ms={r['p50_ms']:.2f};"
             f"p99_ms={r['p99_ms']:.2f};resident={r['resident']};"
             f"faults={r['faults']};evictions={r['evictions']}")
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = main(lambda n, t, d: print(f"{n},{t:.1f},{d}"), smoke=smoke)
    if "--json" in sys.argv:
        path = sys.argv[sys.argv.index("--json") + 1] \
            if len(sys.argv) > sys.argv.index("--json") + 1 \
            and not sys.argv[sys.argv.index("--json") + 1].startswith("--") \
            else "BENCH_many_model.json"
        with open(path, "w") as f:
            json.dump({"benchmark": "many_model", "smoke": smoke,
                       "results": rows}, f, indent=2)
        print(f"wrote {path}")
    if smoke:
        print("many_model_bench --smoke OK")
