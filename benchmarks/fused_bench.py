"""The fused-backend benchmark: one megakernel iteration vs the unfused
per-stage StepProgram path.

Two kinds of rows, both defended by the perf gate against the committed
``BENCH_fused.json``:

* ``fused/{megakernel,unfused}/D*`` — DEVICE-MODELED step times from
  `launch.analysis.roofline` over the exact cost dicts the launch layer
  derives (`megastep_launch_params` for the megakernel; the multi-pass
  cost of the unfused featurize -> gradient -> combine pipeline for the
  baseline). These are deterministic — the gate pins the cost model
  itself, so a block-sizing or cost-accounting regression fails CI on
  any host. At memory-bound D the megakernel reads the (T, D) feature
  tiles ONCE with theta/theta_hat/gamma/neighbors VMEM-resident, while
  the unfused path streams phi twice (forward + gradient) and round-trips
  the residual/gradient intermediates through HBM — the modeled fused
  step beats the unfused baseline at every D >= 4096.

* ``fused/*_interpret/D*`` — MEASURED wall time of the interpret-mode
  megakernel and the jitted blockwise reference on this (CPU) host:
  the plumbing-overhead regression tripwire. Interpret mode emulates the
  grid walk, so these rows say nothing about device speed — that is what
  the modeled rows are for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels.coke_update.coke_update import (megastep_launch_params,
                                                  coke_megastep)
from repro.kernels.coke_update.ref import coke_megastep_ref
from repro.launch import analysis

N_AGENTS = 8
N_SAMPLES = 128
N_NBR = 2  # ring


def unfused_cost(n_agents: int, n_samples: int, dim: int,
                 n_nbr: int) -> dict:
    """HBM-traffic / flop model of the per-stage path at the same padded
    shapes as the megakernel: forward predictions (read phi, theta; write
    preds), data gradient (read phi again + resid; write g), and the
    consensus combine + theta update (read theta/hat/gamma/g/neighbors,
    write gaug and theta_new)."""
    lp = megastep_launch_params(n_agents, n_samples, dim, n_nbr)
    Tp, Dp = lp.padded_t, lp.padded_d
    flops = float(n_agents) * (4.0 * Tp * Dp + 12.0 * Dp)
    bytes_accessed = 4.0 * n_agents * (
        2.0 * Tp * Dp        # phi streamed twice: forward + gradient
        + 3.0 * Tp           # preds written, resid written + read
        + (8.0 + n_nbr) * Dp  # theta x2, hat, gamma, g x2, gaug x2, nbrs
        + 1.0)
    return {"flops": flops, "bytes accessed": bytes_accessed}


def _operands(n, t, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    return (jax.random.normal(ks[0], (n, d), jnp.float32),
            jax.random.normal(ks[1], (n, d), jnp.float32),
            jax.random.normal(ks[2], (n, d), jnp.float32),
            jax.random.normal(ks[3], (n, t, d), jnp.float32),
            jax.random.normal(ks[4], (n, t), jnp.float32))


def main(emit, smoke: bool = False):
    # device-modeled step times (deterministic; gates the cost model)
    for d in (4096,) if smoke else (4096, 8192, 16384):
        lp = megastep_launch_params(N_AGENTS, N_SAMPLES, d, N_NBR)
        fused_us = lp.roofline["step_s_lower_bound"] * 1e6
        un = analysis.roofline(
            unfused_cost(N_AGENTS, N_SAMPLES, d, N_NBR), {})
        unfused_us = un["step_s_lower_bound"] * 1e6
        emit(f"fused/megakernel/D{d}", fused_us,
             f"roofline model ({lp.roofline['dominant']}-bound "
             f"bt={lp.block_t})")
        emit(f"fused/unfused/D{d}", unfused_us,
             f"roofline model ({un['dominant']}-bound; phi streamed 2x)")

    # measured interpret-mode wall time (CPU plumbing tripwire)
    kw = dict(rho=0.1, lam=1e-2, lr=0.05, offsets=(1,))
    for d in (1024,) if smoke else (1024, 4096):
        ops = _operands(N_AGENTS, N_SAMPLES, d)
        t_k = time_call(lambda: coke_megastep(*ops, **kw), iters=5)
        t_r = time_call(lambda: coke_megastep_ref(*ops, **kw), iters=5)
        emit(f"fused/megakernel_interpret/D{d}", t_k,
             f"N={N_AGENTS},T={N_SAMPLES} interpret walk")
        emit(f"fused/unfused_interpret/D{d}", t_r,
             "jitted blockwise reference, same shapes")


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
