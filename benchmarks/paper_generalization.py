"""Theorem 3: generalization vs number of random features, with the COKE
runs driven through `repro.api.fit` and scored through the deployable
`KernelModel` surface (`FitResult.to_model()` → `evaluate`).

Validates the trend the theorem predicts: test risk decreases (then
saturates near the lambda floor) as L grows past the
O(sqrt(T) log d_K^lambda) sufficiency threshold.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.api import PAPER_SETUPS, FitConfig, build_problem, fit
from repro.core import rff, ridge


def run(dataset: str = "synthetic", Ls=(10, 25, 50, 100, 200),
        iters: int = 400, samples: int = 300):
    base = PAPER_SETUPS[dataset]
    rows = []
    for L in Ls:
        cfg = FitConfig(algorithm="coke",
                        krr=dataclasses.replace(base, num_features=L),
                        num_iters=iters)
        built = build_problem(cfg, samples_override=samples)
        res = fit(cfg, problem=built.problem)
        model = res.to_model(built.rff_params)
        metrics = model.evaluate(built.x_test, built.y_test)
        rows.append({"L": L,
                     "train_mse": float(res.train_mse[-1]),
                     "test_mse": metrics["test_mse"]})
    return rows


def dkl_and_sufficient_L(dataset: str = "synthetic", samples: int = 60):
    """Effective degrees of freedom + the Thm-3 sufficient L on a small
    subsample (the kernel matrix is O(T^2))."""
    cfg = PAPER_SETUPS[dataset]
    from repro.data.synthetic import paper_synthetic
    ds = paper_synthetic(num_agents=4, samples_per_agent=samples,
                         seed=cfg.seed)
    X = jnp.asarray(ds.x.reshape(-1, ds.input_dim))
    K = rff.exact_gaussian_kernel(X, X, cfg.bandwidth)
    T = K.shape[0]
    lam = 1.0 / jnp.sqrt(T)  # the paper's lambda = O(1/sqrt(T)) choice
    d = float(ridge.effective_degrees_of_freedom(K, float(lam)))
    L_suff = ridge.sufficient_features(K, float(lam))
    return {"T": T, "d_K_lambda": d, "sufficient_L": L_suff}


def main(emit):
    rows = run()
    for r in rows:
        emit(f"paper_generalization/L{r['L']}", 0.0,
             f"train={r['train_mse']:.3e};test={r['test_mse']:.3e}")
    big_L_better = rows[-1]["test_mse"] <= rows[0]["test_mse"]
    emit("paper_generalization/claim_more_features_help", 0.0,
         str(big_L_better))
    info = dkl_and_sufficient_L()
    emit("paper_generalization/dof", 0.0,
         f"T={info['T']};d_K_lambda={info['d_K_lambda']:.1f};"
         f"sufficient_L={info['sufficient_L']:.0f}")


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
