"""Benchmark entry point: one function per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV and, with ``--json``,
writes one consolidated machine-readable record per run.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only a,b] [--smoke] [--json [F]]

--only   comma-separated suite names (default: all).
--smoke  pass smoke=True to every suite whose main() accepts it — the
         CI-sized fast path; suites without a smoke knob run as usual.
--json   write all emitted rows to F (default ``BENCH_all.json`` at the
         repo root, or ``BENCH_<suite>.json`` when --only names exactly
         one suite) — the artifact CI uploads per run.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import platform
import subprocess
import time
from datetime import datetime, timezone

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _apply_xla_flags() -> None:
    """The olmax runner recipe (SNIPPETS.md): quiet the TF logging spew
    and pin the host-platform device count before jax initializes its
    backend. `--xla_step_marker_location=1` (step marker on the outer
    while loop — what profilers key trace slices on) is applied only
    when a TPU runtime is present: XLA on CPU hosts aborts at startup on
    that flag. TPU presence means actual hardware (/dev/accel* device
    nodes, the TPU-VM contract) or an explicit JAX_PLATFORMS=tpu — NOT
    merely an installed libtpu wheel, which CPU test containers carry
    too. Flags the caller already set in $XLA_FLAGS win.

    Called from the __main__ entry only: in-process callers of `main()`
    (tests, notebooks) keep their environment untouched — mutating
    $XLA_FLAGS mid-process would leak into any subprocess they spawn."""
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    flags = ["--xla_force_host_platform_device_count=1"]
    on_tpu = ("tpu" in os.environ.get("JAX_PLATFORMS", "").lower()
              or any(os.path.exists(f"/dev/accel{i}") for i in range(8)))
    if on_tpu:
        flags.append("--xla_step_marker_location=1")
    existing = os.environ.get("XLA_FLAGS", "")
    extra = " ".join(f for f in flags if f.split("=")[0] not in existing)
    if extra:
        os.environ["XLA_FLAGS"] = f"{existing} {extra}".strip()


def _git_sha() -> str | None:
    """HEAD sha for provenance-stamping BENCH_*.json (None outside git)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_ROOT, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return None


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("only", nargs="?", default=None,
                        help="legacy positional form of --only")
    parser.add_argument("--only", dest="only_flag", default=None,
                        help="comma-separated suite names to run")
    parser.add_argument("--smoke", action="store_true",
                        help="CI-sized runs for suites that support it")
    parser.add_argument("--json", nargs="?", const="", default=None,
                        metavar="PATH",
                        help="write consolidated results as JSON")
    args = parser.parse_args(argv)

    rows: list[dict] = []

    def _emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}", flush=True)
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    t0 = time.time()
    from benchmarks import (big_d_bench, fused_bench, gossip_bench,
                            kernel_bench, many_model_bench,
                            paper_comm_cost, paper_convergence,
                            paper_generalization, paper_online,
                            personalize_bench, roofline,
                            serve_kernel_bench)

    suites = [
        ("paper_convergence", paper_convergence.main),   # Figs 1-2, Tab 1/2/4/5
        ("paper_comm_cost", paper_comm_cost.main),       # Fig 3, Tab 3/6
        ("paper_generalization", paper_generalization.main),  # Thm 3
        ("paper_online", paper_online.main),             # streaming regret/bits
        ("kernels", kernel_bench.main),
        ("fused", fused_bench.main),                     # megakernel vs unfused
        ("serve_kernel", serve_kernel_bench.main),       # deployment surface
        ("many_model", many_model_bench.main),           # multi-tenant store
        ("big_d", big_d_bench.main),                     # matrix-free CG sweep
        ("gossip", gossip_bench.main),                   # async agent-axis
        ("personalize", personalize_bench.main),         # learned-graph vs consensus
        ("roofline", roofline.main),                     # from dry-run cache
    ]
    known = {name for name, _ in suites}
    selected = args.only_flag if args.only_flag is not None else args.only
    only = None
    if selected is not None:
        only = {s.strip() for s in selected.split(",") if s.strip()}
        if not only:
            # an empty/whitespace --only must not degrade into "run all":
            # CI invocations build the suite list programmatically, and a
            # silently-universal run burns the full benchmark budget
            parser.error("--only selected no suites; "
                         f"choose from {sorted(known)}")
        unknown = only - known
        if unknown:
            parser.error(f"unknown suite(s) {sorted(unknown)}; "
                         f"choose from {sorted(known)}")

    for name, fn in suites:
        if only is not None and name not in only:
            continue
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            fn(_emit, **kwargs)
        except Exception as e:  # keep the harness running; report
            _emit(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
    _emit("total_wall_s", (time.time() - t0) * 1e6, "")

    if args.json is not None:
        path = args.json
        if not path:
            stem = f"BENCH_{next(iter(only))}" \
                if only and len(only) == 1 else "BENCH_all"
            path = os.path.join(_ROOT, f"{stem}.json")
        record = {
            "suites": sorted(only) if only else sorted(known),
            "smoke": args.smoke,
            "platform": platform.platform(),
            "python": platform.python_version(),
            "git_sha": _git_sha(),
            "timestamp": datetime.now(timezone.utc).isoformat(
                timespec="seconds"),
            "wall_s": time.time() - t0,
            "results": rows,
        }
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"wrote {path}", flush=True)


if __name__ == "__main__":
    _apply_xla_flags()   # process entry: before jax initializes
    main()
