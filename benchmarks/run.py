"""Benchmark entry point: one function per paper table/figure + kernels +
roofline. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import time


def _emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def main() -> None:
    t0 = time.time()
    from benchmarks import (big_d_bench, kernel_bench, paper_comm_cost,
                            paper_convergence, paper_generalization,
                            paper_online, roofline, serve_kernel_bench)

    suites = [
        ("paper_convergence", paper_convergence.main),   # Figs 1-2, Tab 1/2/4/5
        ("paper_comm_cost", paper_comm_cost.main),       # Fig 3, Tab 3/6
        ("paper_generalization", paper_generalization.main),  # Thm 3
        ("paper_online", paper_online.main),             # streaming regret/bits
        ("kernels", kernel_bench.main),
        ("serve_kernel", serve_kernel_bench.main),       # deployment surface
        ("big_d", big_d_bench.main),                     # matrix-free CG sweep
        ("roofline", roofline.main),                     # from dry-run cache
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in suites:
        if only and only != name:
            continue
        try:
            fn(_emit)
        except Exception as e:  # keep the harness running; report
            _emit(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
    _emit("total_wall_s", (time.time() - t0) * 1e6, "")


if __name__ == "__main__":
    main()
