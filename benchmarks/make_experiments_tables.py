"""Regenerate the machine-written tables of EXPERIMENTS.md from the dry-run
cache: §Dry-run (per-pair lowering status + memory) and §Roofline (three
terms + dominant + useful fraction). Run after any dry-run sweep:

  PYTHONPATH=src python -m benchmarks.make_experiments_tables
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "experiments_tables.md")

ARCH_ORDER = ["internvl2-1b", "granite-3-8b", "zamba2-2.7b",
              "deepseek-v2-lite-16b", "mamba2-2.7b", "minicpm3-4b",
              "seamless-m4t-medium", "mixtral-8x7b", "qwen3-1.7b",
              "llama3-405b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_baseline():
    rows = {}
    for f in glob.glob(os.path.join(RESULTS, "*.json")):
        r = json.load(open(f))
        if (r.get("strategy", "allreduce") != "allreduce" or r.get("fsdp")
                or "seqpar" in f or "mb16" in f or "puredp" in f
                or "headaligned" in f):
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh_kind"))
        rows[key] = r
    return rows


def fmt_gb(x):
    return f"{(x or 0) / 1e9:.1f}"


def main():
    rows = load_baseline()
    lines = ["## §Dry-run — every (arch × shape × mesh) lowers + compiles",
             "",
             "Meshes: single = 16×16 (data, model) = 256 chips; multi = "
             "2×16×16 (pod, data, model) = 512 chips. bf16 params; "
             "ShapeDtypeStruct inputs (zero allocation). `arg`/`temp` are "
             "per-device bytes from `compiled.memory_analysis()`.",
             "",
             "| arch | shape | mesh | status | params | arg GB/dev | "
             "temp GB/dev | collective GB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("single", "multi"):
                r = rows.get((arch, shape, mesh))
                if r is None:
                    continue
                if r.get("status") != "ok":
                    n_skip += 1
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"{r.get('status')} (by design) | — | — |"
                                 f" — | — |")
                    continue
                n_ok += 1
                m, rf = r["memory"], r["roofline"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{r['params'] / 1e9:.2f}B | "
                    f"{fmt_gb(m['argument_bytes'])} | "
                    f"{fmt_gb(m['temp_bytes'])} | "
                    f"{rf['collective_bytes_per_device'] / 1e9:.1f} |")
    lines.append("")
    lines.append(f"**{n_ok} ok, {n_skip} skipped-by-design** "
                 "(seamless-m4t × long_500k; see DESIGN.md).")
    lines.append("")

    lines += ["## §Roofline — single-pod (16×16), per device, per step",
              "",
              "compute = dot_FLOPs/197e12, memory = HBM-traffic proxy/819e9,",
              "collective = collective-operand-bytes/50e9 (all trip-count-",
              "corrected from the compiled HLO; seconds). useful = "
              "MODEL_FLOPS (6·N·D train / 2·N·D serve) ÷ global HLO FLOPs.",
              "",
              "| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | useful | what moves the dominant term |",
              "|---|---|---|---|---|---|---|---|"]
    NOTES = {
        ("train_4k",): "fuse attention (Pallas flash) to kill score/mask "
                       "HBM round-trips; seq-parallel residual",
        ("prefill_32k",): "flash attention (32k scores dominate traffic)",
        ("decode_32k",): "cache reads are the floor — batch more requests",
        ("long_500k",): "B=1 replicates compute; batch or shard sequence",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape, "single"))
            if r is None or r.get("status") != "ok":
                continue
            rf = r["roofline"]
            note = NOTES[(shape,)]
            if arch == "mamba2-2.7b" and shape == "train_4k":
                note = "head-aligned projections (done, §Perf B)"
            lines.append(
                f"| {arch} | {shape} | {rf['compute_s']:.2e} | "
                f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
                f"{rf['dominant']} | {rf['useful_fraction']:.2f} | {note} |")
    lines.append("")

    with open(OUT, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {OUT}: {n_ok} ok rows")


if __name__ == "__main__":
    main()
