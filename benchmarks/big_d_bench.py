"""Big-D scaling: the matrix-free CG primal vs the Cholesky primal.

The Cholesky primal prefactors a dense per-agent (D, D) system — O(N D^2)
memory, O(D^3) setup — which caps the RF dimension at a few thousand. The
CG primal (`primal="cg"`) only ever applies phi.T @ (phi @ v), so its
working set stays O(N Ti D) at any D. This bench sweeps
D in {256, 4096, 16384, 65536}, reporting per-iteration wall-clock, primal
setup time, and peak compiled memory for each mode that fits (run as a
module from the repo root — it imports benchmarks.common):

    python -m benchmarks.big_d_bench            # full sweep
    python -m benchmarks.big_d_bench --smoke    # CI: D in {256, 1024}

Cholesky rows stop at D=4096 (the last size whose factors fit a laptop:
8 agents x 4096^2 floats = 0.5 GB; at 16384 they would want 8 GB). The CG
rows keep going — that is the point. The derived column also reports
`dd_arrays`, the number of (D, D)-shaped intermediates in the step's
jaxpr: 0 for CG at every D (the acceptance criterion, also pinned in
tests/test_big_d.py), > 0 for Cholesky.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.api import FitConfig, KRRConfig, build_problem
from repro.core import admm

FULL_DIMS = (256, 4096, 16384, 65536)
SMOKE_DIMS = (256, 1024)
CHOLESKY_CAP = 4096          # largest D whose (D, D) factors we dare build

NUM_AGENTS = 8
SAMPLES = 128


def count_dd_arrays(jaxpr, d: int) -> int:
    """Number of (d, d)-shaped values anywhere in a jaxpr (recursively) —
    the 'did this path materialize a (D, D) array' detector."""
    hits = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            if tuple(shape[-2:]) == (d, d):
                hits += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            hits += count_dd_arrays(sub, d)
    return hits


def _peak_bytes(step, *args) -> int | None:
    """Compiled peak memory when the backend reports it (CPU/TPU XLA
    expose generated-code memory analysis; None when unavailable)."""
    try:
        ma = step.lower(*args).compile().memory_analysis()
        if ma is None:
            return None
        return int(ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                   ma.output_size_in_bytes)
    except Exception:
        return None


def bench_mode(emit, problem, policy, dim: int, mode: str,
               iters: int) -> None:
    setup_us = 0.0
    chol = None
    if mode == "cholesky":
        t0 = time.perf_counter()
        chol = jax.block_until_ready(admm._ridge_factors(problem))
        setup_us = (time.perf_counter() - t0) * 1e6

    # problem/chol enter as ARGUMENTS, not closure constants — XLA would
    # otherwise constant-fold the embedded arrays (slow compiles, and the
    # folding time pollutes the iteration timings)
    def step_fn(problem, chol, state):
        return admm.coke_step(problem, policy, state, chol,
                              primal="cg" if mode == "cg" else "auto")

    step = jax.jit(step_fn)
    state0 = admm.init_state(problem, policy=policy)
    dd = count_dd_arrays(
        jax.make_jaxpr(step_fn)(problem, chol, state0).jaxpr, dim)
    if mode == "cg" and dd:
        raise AssertionError(
            f"CG primal materialized {dd} (D, D) arrays at D={dim}")
    peak = _peak_bytes(step, problem, chol, state0)
    us = time_call(step, problem, chol, state0, iters=iters)
    emit(f"big_d/{mode}/D{dim}", us,
         f"dd_arrays={dd};setup_us={setup_us:.0f};"
         f"peak_bytes={'n/a' if peak is None else peak};"
         f"agents={NUM_AGENTS};samples={SAMPLES}")


def main(emit, smoke: bool = False) -> None:
    dims = SMOKE_DIMS if smoke else FULL_DIMS
    iters = 3 if smoke else 5
    for dim in dims:
        cfg = FitConfig(
            krr=KRRConfig(num_agents=NUM_AGENTS, samples_per_agent=SAMPLES,
                          num_features=dim, lam=1e-3, rho=1e-2, seed=0),
            graph="ring", algorithm="coke", censor_v=0.5, censor_mu=0.97)
        problem = build_problem(cfg).problem
        policy = cfg.resolved_comm
        policy = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), policy)
        if dim <= CHOLESKY_CAP:
            bench_mode(emit, problem, policy, dim, "cholesky", iters)
        bench_mode(emit, problem, policy, dim, "cg", iters)


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"),
         smoke="--smoke" in sys.argv[1:])
