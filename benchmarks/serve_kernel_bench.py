"""KernelServer latency/throughput smoke benchmark.

Measures the deployment surface end-to-end: per-request latency percentiles
and aggregate rows/s through the microbatching server, for the ref and
fused (Pallas rff) scoring backends, plus the raw jitted scorer's
single-call throughput as the no-batching ceiling.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import time_call
from repro.api import FitConfig, KRRConfig, fit
from repro.serve import KernelServeConfig, KernelServer


def _fit_model(L: int = 128):
    cfg = FitConfig(
        krr=KRRConfig(num_agents=8, samples_per_agent=200, num_features=L,
                      lam=1e-3, rho=5e-2, seed=0),
        algorithm="coke", censor_v=0.1, censor_mu=0.995, num_iters=200)
    return fit(cfg).to_model()


def _drive(server: KernelServer, queries) -> dict:
    latencies = []

    def client(x):
        t0 = time.perf_counter()
        server.submit(x).result()
        latencies.append((time.perf_counter() - t0) * 1e3)

    threads = [threading.Thread(target=client, args=(q,)) for q in queries]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lat = sorted(latencies)
    rows = sum(q.shape[0] for q in queries)
    return {"rows_per_s": rows / wall,
            "p50_ms": lat[len(lat) // 2],
            "p95_ms": lat[int(len(lat) * 0.95)],
            "batches": server.stats()["batches"],
            "requests": len(queries)}


def run(num_requests: int = 64, backends=("ref", "fused")):
    model = _fit_model()
    rng = np.random.default_rng(0)
    queries = [rng.uniform(size=(int(b), model.input_dim)).astype(np.float32)
               for b in rng.integers(1, 32, size=num_requests)]
    rows = {}
    for backend in backends:
        with KernelServer(model,
                          KernelServeConfig(max_delay_ms=2.0,
                                            backend=backend)) as server:
            server.predict(queries[0])  # warm jit before timing
            rows[backend] = _drive(server, queries)
    # no-batching ceiling: one fused device call on the full row set
    x = np.concatenate(queries)
    us = time_call(lambda: model.predict(x, backend="ref"))
    rows["raw_single_call"] = {"rows_per_s": x.shape[0] / (us / 1e6),
                               "rows": x.shape[0]}
    return rows


def main(emit):
    rows = run()
    for backend in ("ref", "fused"):
        r = rows[backend]
        emit(f"serve_kernel/{backend}", r["p50_ms"] * 1e3,
             f"rows_per_s={r['rows_per_s']:.0f};p95_ms={r['p95_ms']:.2f};"
             f"batches={r['batches']};requests={r['requests']}")
    r = rows["raw_single_call"]
    emit("serve_kernel/raw_single_call", 0.0,
         f"rows_per_s={r['rows_per_s']:.0f};rows={r['rows']}")


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
