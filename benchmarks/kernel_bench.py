"""Microbenchmarks: Pallas kernels vs their pure-jnp references.

The wrappers' `interpret=None` resolves via
`repro.kernels.runtime.resolve_interpret` — compiled on TPU/GPU,
interpret on CPU ($REPRO_PALLAS_INTERPRET overrides). The row names
carry the resolved mode, so compiled-device records are never compared
against interpret-mode ones; on CPU the pallas rows only sanity-check
plumbing overhead."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core.rff import draw_rff, featurize_jit
from repro.kernels.coke_update.coke_update import coke_fused_update
from repro.kernels.coke_update.ref import coke_update_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rff.ops import featurize_fused
from repro.kernels.runtime import resolve_interpret


def main(emit):
    mode = "interpret" if resolve_interpret(None) else "compiled"
    # RFF featurizer
    p = draw_rff(jax.random.PRNGKey(0), 77, 128, 1.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2048, 77))
    t_ref = time_call(lambda: featurize_jit(p, x))
    t_ker = time_call(lambda: featurize_fused(p, x))
    emit("kernel/rff/jnp_ref", t_ref, "T=2048,d=77,L=128")
    emit(f"kernel/rff/pallas_{mode}", t_ker, "same shapes")

    # flash attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64))
    k = jax.random.normal(ks[1], (1, 4, 512, 64))
    v = jax.random.normal(ks[2], (1, 4, 512, 64))
    t_ref = time_call(lambda: attention_ref(q, k, v))
    t_ker = time_call(lambda: flash_attention(q, k, v, block_q=128,
                                              block_k=128))
    emit("kernel/flash_attention/jnp_ref", t_ref, "B1 H4 S512 D64 causal")
    emit(f"kernel/flash_attention/pallas_{mode}", t_ker, "same shapes")

    # fused COKE update
    args = [jax.random.normal(kk, (16, 65536))
            for kk in jax.random.split(jax.random.PRNGKey(3), 6)]
    t_ref = time_call(lambda: coke_update_ref(*args, rho=0.1))
    t_ker = time_call(lambda: coke_fused_update(*args, rho=0.1))
    emit("kernel/coke_update/jnp_ref", t_ref, "N=16,D=65536")
    emit(f"kernel/coke_update/pallas_{mode}", t_ker, "same shapes")


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"))
