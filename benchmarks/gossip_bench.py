"""Gossip-vs-sync scaling along the agent axis: N in {20, 200, 2000}.

The sync simulator iterates every agent through the dense adjacency
matmul; the gossip engine samples ~N/4 participants per tick and runs the
same COKE step through the padded NeighborTable gather — no (N, N) arrays
anywhere on its hot path (the detector from big_d_bench, turned on the
agent axis, is re-checked here on every row). Two row families per N:

    gossip/sync/N{n}     per-iteration wall-clock of the jitted sync step
    gossip/gossip/N{n}   same for the gossip step at participation=0.25

each with derived `final_train_mse` / `comms` from a short fit (gossip
gets 4x the rounds — equal expected per-agent work), plus `nn_uses`, the
number of jaxpr equations consuming an (N, N) value: > 0 for sync, 0 for
gossip. --smoke shrinks iteration counts but keeps the SAME N set, so CI
smoke rows match the committed full-run baseline by name and the perf
gate (benchmarks/perf_gate.py) can compare per-iteration latencies.

    python -m benchmarks.gossip_bench            # full
    python -m benchmarks.gossip_bench --smoke    # CI
"""
from __future__ import annotations

import sys

import time

import jax
import numpy as np

from repro.api import ChurnSchedule, FitConfig, KRRConfig, build_problem, fit
from repro.core import admm
from repro.core import gossip as G

AGENT_COUNTS = (20, 200, 2000)
PARTICIPATION = 0.25
SAMPLES = 4
FEATURES = 32


def time_min(fn, *args, iters: int, warmup: int = 3) -> float:
    """Best-of-N wall time per call in microseconds. The perf gate
    compares these rows across machines/runs at a 1.5x factor; for
    sub-millisecond steps the MIN is the noise-robust estimator (a median
    still swings 2x+ under co-tenant CPU spikes, the best-case latency
    does not) — hence not common.time_call here."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def count_nn_uses(jaxpr, n: int) -> int:
    """Equations consuming an (n, n)-shaped value (recursively) — the
    agent-axis twin of big_d_bench.count_dd_arrays, counting USES so a
    step that merely reads the dense adjacency invar is still caught."""
    hits = 0
    for eqn in jaxpr.eqns:
        for var in eqn.invars:
            shape = getattr(getattr(var, "aval", None), "shape", ())
            if tuple(shape[-2:]) == (n, n):
                hits += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            hits += count_nn_uses(sub, n)
    return hits


def _bench_one(emit, cfg, mode: str, fit_iters: int, timing_iters: int):
    n = cfg.krr.num_agents
    problem = build_problem(cfg).problem
    policy = cfg.resolved_comm

    if mode == "gossip":
        run_cfg = cfg.replace(exec="gossip", participation=PARTICIPATION,
                              num_iters=fit_iters * 4)
        table = G.NeighborTable.from_adjacency(np.asarray(problem.adjacency))
        plan = ChurnSchedule().plan(n, participation=PARTICIPATION)

        def step_fn(problem, state, table, plan):
            return G.gossip_coke_step(problem, policy, state, table, plan,
                                      primal="cg")

        step_args = (problem, admm.init_state(problem, policy=policy),
                     table, plan)
    else:
        run_cfg = cfg.replace(num_iters=fit_iters)

        def step_fn(problem, state):
            return admm.coke_step(problem, policy, state, None, primal="cg")

        step_args = (problem, admm.init_state(problem, policy=policy))

    nn = count_nn_uses(jax.make_jaxpr(step_fn)(*step_args).jaxpr, n)
    if mode == "gossip" and nn:
        raise AssertionError(
            f"gossip step consumed {nn} (N, N) values at N={n}")
    us = time_min(jax.jit(step_fn), *step_args, iters=timing_iters)

    res = fit(run_cfg, problem=problem)
    emit(f"gossip/{mode}/N{n}", us,
         f"final_train_mse={float(res.history['train_mse'][-1]):.5f};"
         f"comms={int(res.history['comms'][-1])};"
         f"iters={run_cfg.resolved_iters};nn_uses={nn};"
         f"participation={PARTICIPATION if mode == 'gossip' else 1.0}")


def main(emit, smoke: bool = False) -> None:
    fit_iters = 15 if smoke else 100
    # steps are sub-10ms even at N=2000: a generous sample count costs
    # nothing and keeps the 1.5x perf gate out of timing-jitter territory
    timing_iters = 30 if smoke else 50
    for n in AGENT_COUNTS:
        cfg = FitConfig(
            krr=KRRConfig(num_agents=n, samples_per_agent=SAMPLES,
                          num_features=FEATURES, lam=1e-3, rho=0.1, seed=0),
            graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
            primal="cg")
        for mode in ("sync", "gossip"):
            _bench_one(emit, cfg, mode, fit_iters, timing_iters)


if __name__ == "__main__":
    main(lambda n, t, d: print(f"{n},{t:.1f},{d}"),
         smoke="--smoke" in sys.argv[1:])
