"""Optimizers from scratch (no optax in the container): SGD(+momentum) and
AdamW, as pure pytree transforms. Used both by the deep-net training loop and
as the inexact inner solver of the consensus (ADMM) strategies."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=(), meta_fields=("kind", "lr", "beta1", "beta2", "eps",
                                      "weight_decay", "momentum",
                                      "grad_clip"))
@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | sgd
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.0        # sgd only
    grad_clip: float = 0.0       # 0 = off (global-norm clip)


def init_opt_state(cfg: OptConfig, params):
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.kind == "adamw":
        return {"m": zeros(), "v": zeros(),
                "count": jnp.zeros((), jnp.int32)}
    if cfg.momentum:
        return {"m": zeros(), "count": jnp.zeros((), jnp.int32)}
    return {"count": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def opt_update(cfg: OptConfig, grads, state, params):
    """-> (updates to ADD to params, new_state)."""
    if cfg.grad_clip:
        gn = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    count = state["count"] + 1

    if cfg.kind == "adamw":
        m = jax.tree.map(
            lambda m_, g: cfg.beta1 * m_ + (1 - cfg.beta1)
            * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: cfg.beta2 * v_ + (1 - cfg.beta2)
            * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - cfg.beta1 ** c
        bc2 = 1 - cfg.beta2 ** c
        updates = jax.tree.map(
            lambda m_, v_, p: (-cfg.lr * ((m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + cfg.eps)
                               + cfg.weight_decay
                               * p.astype(jnp.float32))).astype(p.dtype),
            m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    # SGD
    if cfg.momentum:
        m = jax.tree.map(lambda m_, g: cfg.momentum * m_
                         + g.astype(jnp.float32), state["m"], grads)
        updates = jax.tree.map(lambda m_, p: (-cfg.lr * m_).astype(p.dtype),
                               m, params)
        return updates, {"m": m, "count": count}
    updates = jax.tree.map(lambda g, p: (-cfg.lr * g).astype(p.dtype),
                           grads, params)
    return updates, {"count": count}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
