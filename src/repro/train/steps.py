"""Train-step factories.

`make_train_step(cfg, opt_cfg, ccfg)` returns (init_fn, step_fn):

  * allreduce: canonical DP+TP step — mean loss over the global batch, XLA
    inserts the gradient all-reduce across the batch axes.
  * dkla / coke / coke_et / cta: the paper's decentralized strategies — the
    batch carries a leading agent axis, each agent computes a local gradient
    (vmap), and the consensus layer couples agents over the ring.

Both step kinds are pure (state, batch) -> (state, metrics) functions, jit
/ lower-able with explicit shardings by the launcher and the dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import consensus as cns
from repro.models import model as model_lib
from repro.models.common import ModelConfig
from repro.optim.optimizers import (OptConfig, apply_updates,
                                    init_opt_state, opt_update)


def make_allreduce_step(cfg: ModelConfig, opt_cfg: OptConfig,
                        microbatches: int = 1):
    def init_fn(key):
        params = model_lib.init_params(cfg, key)
        return {"params": params,
                "opt": init_opt_state(opt_cfg, params),
                "step": jnp.zeros((), jnp.int32)}

    def _grads(params, batch):
        if microbatches == 1:
            (loss, extras), grads = jax.value_and_grad(
                model_lib.loss_fn, has_aux=True)(params, cfg, batch)
            return loss, extras, grads

        # gradient accumulation: scan over microbatches so only one
        # microbatch's activations are live at a time
        def split(x):
            return x.reshape(microbatches, x.shape[0] // microbatches,
                             *x.shape[1:])
        mbatch = {k: split(v) for k, v in batch.items()}

        def body(carry, mb):
            g_acc, loss_acc, aux_acc = carry
            (loss, extras), g = jax.value_and_grad(
                model_lib.loss_fn, has_aux=True)(params, cfg, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                 g_acc, g)
            return (g_acc, loss_acc + loss, aux_acc + extras["aux"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_acc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32),
                   jnp.zeros((), jnp.float32)), mbatch)
        scale = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * scale, g_acc)
        return loss_sum * scale, {"nll": loss_sum * scale,
                                  "aux": aux_sum * scale}, grads

    def step_fn(state, batch):
        loss, extras, grads = _grads(state["params"], batch)
        updates, opt = opt_update(opt_cfg, grads, state["opt"],
                                  state["params"])
        params = apply_updates(state["params"], updates)
        metrics = {"loss": loss, **extras}
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                metrics)

    return init_fn, step_fn


def make_consensus_step(cfg: ModelConfig, opt_cfg: OptConfig,
                        ccfg: cns.ConsensusConfig, num_agents: int,
                        comm=None):
    """Batch layout: every leaf gains a leading agent axis (N, ...).

    comm — optional core.comm policy chain governing the broadcast
    (censor / quantize / drop); None = ccfg's legacy censor knobs."""

    def init_fn(key):
        params = model_lib.init_params(cfg, key)
        stacked = cns.stack_params(params, num_agents)
        return {"params": stacked,
                "consensus": cns.init_consensus_state(ccfg, opt_cfg,
                                                      stacked, comm=comm)}

    def _local_grads(params_stacked, batch_stacked):
        def local(p, b):
            (loss, extras), g = jax.value_and_grad(
                model_lib.loss_fn, has_aux=True)(p, cfg, b)
            return loss, g
        loss, grads = jax.vmap(local)(params_stacked, batch_stacked)
        return jnp.mean(loss), grads

    def step_fn(state, batch):
        loss, grads = _local_grads(state["params"], batch)
        params, cstate, metrics = cns.consensus_update(
            ccfg, opt_cfg, state["params"], grads, state["consensus"],
            comm=comm)
        metrics = {"loss": loss, "comms": cstate["comms"], **metrics}
        if ccfg.track_gap:  # full-param all-reduce; off in the hot path
            metrics["consensus_gap"] = cns.consensus_gap(params)
        return {"params": params, "consensus": cstate}, metrics

    def local_step_fn(state, batch):
        """coke_et censored round: no agent-axis collectives lowered."""
        loss, grads = _local_grads(state["params"], batch)
        params, cstate = cns.local_update(opt_cfg, state["params"], grads,
                                          state["consensus"])
        return {"params": params, "consensus": cstate}, {"loss": loss}

    return init_fn, step_fn, local_step_fn


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    ccfg: cns.ConsensusConfig | None = None,
                    num_agents: int = 1, microbatches: int = 1,
                    comm=None):
    if ccfg is None or ccfg.strategy == "allreduce":
        init_fn, step_fn = make_allreduce_step(cfg, opt_cfg, microbatches)
        return init_fn, step_fn, None
    return make_consensus_step(cfg, opt_cfg, ccfg, num_agents, comm=comm)


def agent_batch(batch: dict, num_agents: int) -> dict:
    """Reshape a global batch (B, ...) into (N, B/N, ...) agent shards."""
    def r(x):
        return x.reshape(num_agents, x.shape[0] // num_agents, *x.shape[1:])
    return {k: r(v) for k, v in batch.items()}
