from repro.train.steps import agent_batch, make_train_step  # noqa: F401
