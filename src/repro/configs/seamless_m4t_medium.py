"""seamless-m4t-medium [audio] — encoder-decoder, multimodal
[arXiv:2308.11596].

Transformer backbone only: the mel-spectrogram + conv feature extractor is a
stub; `input_specs()` provides precomputed frame embeddings (B, S_enc, d).
12 encoder + 12 decoder layers. Decode shapes run the decoder against a
cached encoder memory. `long_500k` is skipped for this arch (DESIGN.md).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    source="arXiv:2308.11596 (SeamlessM4T medium)",
)
