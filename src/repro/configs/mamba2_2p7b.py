"""mamba2-2.7b [ssm] — pure SSD (state-space duality), attention-free
[arXiv:2405.21060].

d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, state N=128.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    source="arXiv:2405.21060 (Mamba2 / SSD)",
)
