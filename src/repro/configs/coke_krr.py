"""The paper's own workload as a first-class config: decentralized kernel
ridge regression (COKE / DKLA / CTA) — Section 5 setups.

`KRRConfig` is the problem half of the unified run description: compose it
into a `repro.api.FitConfig` (which adds algorithm, backend, graph family
and censor overrides) and run it with `repro.api.fit`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KRRConfig:
    name: str = "coke-krr"
    dataset: str = "synthetic"      # synthetic | heterogeneous |
                                    # toms_hardware | twitter |
                                    # twitter_large | energy | air_quality
    num_agents: int = 20
    samples_per_agent: int = 500
    num_tasks: int = 3              # heterogeneous only: K latent tasks
    num_features: int = 100         # L random features
    bandwidth: float = 1.0          # training kernel bandwidth (Sec 5.3)
    lam: float = 5e-5               # regularization lambda
    rho: float = 1e-2               # ADMM penalty/step
    censor_v: float = 1.0           # h(k) = v * mu^k
    censor_mu: float = 0.95
    graph_p: float = 0.3            # ER attachment probability
    num_iters: int = 1000
    seed: int = 0
    mapping: str = "cos_bias"       # Eq. (13); "cos_sin" = Eq. (12)


# Table/figure parameterizations from Section 5.3 (real-data tables use
# h(k) = c * mu^k with the listed c, mu, lambda, bandwidth, L).
PAPER_SETUPS = {
    "synthetic": KRRConfig(dataset="synthetic", num_agents=20, lam=5e-5,
                           rho=1e-2, censor_v=1.0, censor_mu=0.95,
                           bandwidth=1.0, num_features=100),
    "twitter_large": KRRConfig(dataset="twitter_large", num_agents=10,
                               lam=1e-3, rho=1e-2, censor_v=0.5,
                               censor_mu=0.98, bandwidth=1.0,
                               num_features=100),
    "toms_hardware": KRRConfig(dataset="toms_hardware", num_agents=10,
                               lam=1e-2, rho=1e-2, censor_v=0.5,
                               censor_mu=0.95, bandwidth=1.0,
                               num_features=100),
    "energy": KRRConfig(dataset="energy", num_agents=10, lam=1e-3,
                        rho=1e-2, censor_v=0.5, censor_mu=0.98,
                        bandwidth=0.1, num_features=100),
    "air_quality": KRRConfig(dataset="air_quality", num_agents=10, lam=1e-5,
                             rho=1e-2, censor_v=0.9, censor_mu=0.97,
                             bandwidth=0.1, num_features=200),
}

CONFIG = PAPER_SETUPS["synthetic"]
