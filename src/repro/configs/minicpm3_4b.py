"""minicpm3-4b [dense] — MLA attention [hf:openbmb/MiniCPM3-4B].

MLA with q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 (model
card values for the 40-head geometry).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    source="hf:openbmb/MiniCPM3-4B (MLA)",
)
