"""internvl2-1b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Language/decoder transformer only; the vision frontend is a stub per the
assignment carve-out: `input_specs()` provides 256 precomputed patch
embeddings of width d_model.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    prefix_len=256,
    rope_theta=1e6,
    source="arXiv:2404.16821 (InternVL2); InternLM2 LM backbone",
)
