"""Architecture registry: `--arch <id>` resolution."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "internvl2-1b": "repro.configs.internvl2_1b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "llama3-405b": "repro.configs.llama3_405b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_krr_config(setup: str = "synthetic"):
    from repro.configs.coke_krr import PAPER_SETUPS
    return PAPER_SETUPS[setup]
