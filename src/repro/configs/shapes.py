"""The four assigned input shapes and per-(arch, shape) input specs.

`input_specs(cfg, shape)` returns (resolved_cfg, step_kind, specs):
  * resolved_cfg — the config actually lowered (long_500k enables a
    sliding-window variant for full-attention archs, per the assignment),
  * step_kind — "train" | "prefill" | "decode",
  * specs — a dict of jax.ShapeDtypeStruct stand-ins (weak-type-correct,
    shardable, zero allocation).

Shape semantics:
  train_4k     seq_len=4096    global_batch=256   train_step
  prefill_32k  seq_len=32768   global_batch=32    serve prefill
  decode_32k   seq_len=32768   global_batch=128   ONE token, cache=seq_len
  long_500k    seq_len=524288  global_batch=1     ONE token, sub-quadratic only

Modality splits (documented in DESIGN.md):
  vlm   — prefix_len patch embeddings + (seq - prefix) text tokens,
  audio — encoder frames = seq/2, decoder tokens = seq/2 (train/prefill);
          decode uses a 4096-frame cached encoder memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

LONG_WINDOW = 4096  # sliding window enabled for full-attention archs @500k
AUDIO_DECODE_ENC_LEN = 4096


def long_context_mode(cfg: ModelConfig) -> str:
    """How this arch runs long_500k: native | window-variant | skip."""
    if cfg.arch_type in ("ssm", "hybrid"):
        return "native"          # O(1)/windowed state
    if cfg.is_encdec:
        return "skip"            # recorded in DESIGN.md
    if cfg.sliding_window:
        return "native"          # mixtral
    return "window-variant"      # dense/MLA/VLM: SWA override, window 4096


def resolve(cfg: ModelConfig, shape_name: str) -> ModelConfig | None:
    """Config actually used for this shape (None = skipped pair)."""
    if shape_name != "long_500k":
        return cfg
    mode = long_context_mode(cfg)
    if mode == "skip":
        return None
    if mode == "window-variant":
        return cfg.with_overrides(sliding_window=LONG_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_specs(cfg: ModelConfig, B: int, S: int, with_labels: bool):
    """Token/embedding inputs for a full-sequence pass."""
    specs: dict = {}
    if cfg.is_encdec:
        S_enc = S // 2
        S_dec = S - S_enc
        specs["encoder_embeds"] = _sds((B, S_enc, cfg.d_model), cfg.dtype)
        specs["tokens"] = _sds((B, S_dec), jnp.int32)
        if with_labels:
            specs["labels"] = _sds((B, S_dec), jnp.int32)
        return specs
    if cfg.prefix_len:
        P = cfg.prefix_len
        specs["prefix_embeds"] = _sds((B, P, cfg.d_model), cfg.dtype)
        specs["tokens"] = _sds((B, S - P), jnp.int32)
        if with_labels:
            specs["labels"] = _sds((B, S - P), jnp.int32)
        return specs
    specs["tokens"] = _sds((B, S), jnp.int32)
    if with_labels:
        specs["labels"] = _sds((B, S), jnp.int32)
    return specs


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def decode_state_specs(cfg: ModelConfig, B: int, seq_len: int):
    """ShapeDtypeStruct pytree of the serve state via eval_shape (no alloc)."""
    C = cache_len_for(cfg, seq_len)
    enc_len = AUDIO_DECODE_ENC_LEN if cfg.is_encdec else 0
    return jax.eval_shape(
        lambda: model_lib.init_serve_state(cfg, B, C, enc_len=enc_len))


def input_specs(cfg: ModelConfig, shape_name: str):
    """-> (resolved_cfg, step_kind, specs dict) or (None, None, None) if
    the pair is skipped."""
    shape = SHAPES[shape_name]
    rcfg = resolve(cfg, shape_name)
    if rcfg is None:
        return None, None, None
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        return rcfg, "train", _token_specs(rcfg, B, S, with_labels=True)
    if shape.kind == "prefill":
        return rcfg, "prefill", _token_specs(rcfg, B, S, with_labels=False)

    specs = {
        "token": _sds((B, 1), jnp.int32),
        "position": _sds((), jnp.int32),
        "state": decode_state_specs(rcfg, B, S),
    }
    return rcfg, "decode", specs
