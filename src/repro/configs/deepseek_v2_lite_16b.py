"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + fine-grained MoE
[arXiv:2405.04434].

64 routed experts top-6 + 2 shared experts, expert d_ff=1408. (The
assignment line lists both "64e top-6" and "160 routed"; DeepSeek-V2-Lite's
published config is 64 routed — we follow the model card. Real model keeps
layer 0 dense; we make all layers MoE to keep the stack scan-homogeneous —
noted in DESIGN.md.)
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)
