"""Per-architecture configs (exact assigned specs) + input shapes + registry."""
from repro.configs.registry import get_config, get_krr_config, list_archs  # noqa: F401
from repro.configs.shapes import SHAPES, input_specs, long_context_mode  # noqa: F401
