"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242].

54 Mamba2 layers in groups of 6; after each group one *shared* (single set
of weights) GQA attention+MLP block is applied. Per-application KV caches
remain distinct. (The per-application LoRA adapters of the real model are
omitted — recorded in DESIGN.md.)
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    source="arXiv:2411.15242 (Zamba2)",
)
