"""Pure-jnp oracle for the RFF kernel."""
import jax
import jax.numpy as jnp


def rff_ref(x: jax.Array, omega: jax.Array, bias: jax.Array) -> jax.Array:
    L = omega.shape[1]
    proj = jnp.dot(x, omega, preferred_element_type=jnp.float32)
    return (jnp.sqrt(2.0 / L) * jnp.cos(proj + bias[None, :])).astype(x.dtype)
