"""jit'd public entry point for the fused RFF featurizer."""
from __future__ import annotations

import jax

from repro.core.rff import RFFParams
from repro.kernels.rff.rff import rff_pallas


def featurize_fused(params: RFFParams, x: jax.Array,
                    interpret: bool | None = None) -> jax.Array:
    """Drop-in for repro.core.rff.featurize (cos_bias mapping), batched over
    leading dims."""
    if x.ndim > 2:
        flat = x.reshape(-1, x.shape[-1])
        out = rff_pallas(flat, params.omega, params.bias,
                         interpret=interpret)
        return out.reshape(*x.shape[:-1], out.shape[-1])
    return rff_pallas(x, params.omega, params.bias, interpret=interpret)
