"""Pallas TPU kernel: fused random-Fourier featurizer.

phi = sqrt(2/L) * cos(X @ Omega + b)

One VMEM pass fuses the MXU matmul with the VPU cosine + scale — the
XLA-naive version round-trips the (T, L) projection through HBM between the
matmul and the transcendental. Every agent featurizes every sample in every
experiment, so this is the paper workload's compute hot spot.

Tiling: grid (T/bt, L/bl); X tile (bt, d) with d kept whole (assigned
datasets have d <= 96; the wrapper pads d to a lane multiple), Omega tile
(d, bl), bias tile (bl,), out tile (bt, bl). bt/bl default to MXU-aligned
128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _rff_kernel(x_ref, omega_ref, bias_ref, out_ref, *, scale: float):
    proj = jnp.dot(x_ref[...], omega_ref[...],
                   preferred_element_type=jnp.float32)
    out_ref[...] = (scale * jnp.cos(proj + bias_ref[...][None, :])
                    ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_l", "interpret"))
def _rff_pallas(x: jax.Array, omega: jax.Array, bias: jax.Array,
                block_t: int, block_l: int, interpret: bool) -> jax.Array:
    T, d = x.shape
    L = omega.shape[1]
    scale = float((2.0 / L) ** 0.5)

    bt = min(block_t, T)
    bl = min(block_l, L)
    pad_t, pad_l = (-T) % bt, (-L) % bl
    pad_d = (-d) % 8  # sublane alignment for the contracted dim
    xp = jnp.pad(x, ((0, pad_t), (0, pad_d)))
    op = jnp.pad(omega, ((0, pad_d), (0, pad_l)))
    bp = jnp.pad(bias, (0, pad_l))
    Tp, dp = xp.shape
    Lp = op.shape[1]

    out = pl.pallas_call(
        functools.partial(_rff_kernel, scale=scale),
        grid=(Tp // bt, Lp // bl),
        in_specs=[
            pl.BlockSpec((bt, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((dp, bl), lambda i, j: (0, j)),
            pl.BlockSpec((bl,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Lp), x.dtype),
        interpret=interpret,
    )(xp, op, bp)
    return out[:T, :L]


def rff_pallas(x: jax.Array, omega: jax.Array, bias: jax.Array,
               block_t: int = 128, block_l: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """x: (T, d); omega: (d, L); bias: (L,) -> (T, L) features.

    Matches repro.core.rff.featurize with mapping='cos_bias' (incl. the
    1/sqrt(L) normalization). interpret=None resolves via
    repro.kernels.runtime.resolve_interpret (compiled off-CPU)."""
    return _rff_pallas(x, omega, bias, block_t, block_l,
                       resolve_interpret(interpret))
