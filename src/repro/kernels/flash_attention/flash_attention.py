"""Pallas TPU kernel: blockwise (flash) attention forward.

Online-softmax attention with (block_q, block_k) VMEM tiles — the 32k
prefill hot spot. Supports causal and sliding-window masks (the mask logic
mirrors repro.models.attention.blockwise_attention, which is the pure-jnp
oracle/dry-run path).

Grid: (B*H, Sq/bq, Sk/bk) with the Sk axis innermost ("arbitrary"
semantics); m / l / acc live in VMEM scratch across the Sk sweep and the
output tile is written on the last k-step. Tiles default to 128x128 —
MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int, sk_real: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, Dh)
    k = k_ref[0].astype(jnp.float32)            # (bk, Dh)
    v = v_ref[0].astype(jnp.float32)            # (bk, Dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < sk_real  # mask padded keys
    if causal:
        valid &= k_pos <= q_pos
    if window:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_new = acc_prev * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int, block_q: int, block_k: int,
                     interpret: bool) -> jax.Array:
    B, H, Sq, Dh = q.shape
    Sk, Dv = k.shape[2], v.shape[3]
    scale = 1.0 / (Dh ** 0.5)

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pad_q, pad_k = (-Sq) % bq, (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys masked out via causal bound (their positions exceed
        # every real q position) only when causal; for non-causal we mask
        # through a -inf pad on k itself is unsafe -> use explicit l floor.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)),
                    constant_values=0)
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = Sq + pad_q, Sk + pad_k
    nq, nk = Sqp // bq, Skp // bk

    qf = q.reshape(B * H, Sqp, Dh)
    kf = k.reshape(B * H, Skp, Dh)
    vf = v.reshape(B * H, Skp, Dv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, sk_real=Sk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, Dv)[:, :, :Sq]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, Dh); k/v: (B, H, Sk, Dh|Dv) (pre-broadcast GQA).
    Returns (B, H, Sq, Dv). interpret=None resolves via
    repro.kernels.runtime.resolve_interpret (compiled off-CPU)."""
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=resolve_interpret(interpret))
