"""Public entry: GQA-layout wrapper over the flash kernel.

Takes (B, S, H, Dh) activations-layout q and (B, S, KV, *) k/v (the model's
native layout), broadcasts KV groups, and calls the kernel. On TPU this is
the prefill path; the pure-jnp blockwise implementation remains the
XLA-lowerable oracle used by the dry-run.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


def gqa_flash(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
              interpret=None):
    """q: (B,Sq,H,Dh); k/v: (B,Sk,KV,*) -> (B,Sq,H,Dv)."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out.transpose(0, 2, 1, 3)
