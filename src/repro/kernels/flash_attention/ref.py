"""Pure-jnp oracle for the flash-attention kernel: naive full-matrix
softmax attention with the same causal / sliding-window mask semantics."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,Dh); k/v: (B,H,Sk,*) -> (B,H,Sq,Dv)."""
    Sq, Sk = q.shape[2], k.shape[2]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
