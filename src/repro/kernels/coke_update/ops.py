"""Public entry: pytree-level fused COKE update.

Flattens an agent-stacked param pytree to (N, D), runs the fused kernel,
and unflattens g_aug — the drop-in accelerated core for
repro.distributed.consensus.consensus_update.

xi contract (reconciled across the stack): the kernels
(`coke_fused_update`, `coke_megastep`) return xi_sq — the *squared*
censor norm, because squares are what per-block partial sums can emit —
while this pytree-level wrapper returns xi_norm = sqrt(xi_sq), the
quantity the censor policy compares against h(k). The zero pad added to
reach the lane tile contributes exactly zero to either (pinned by a
non-multiple-of-128 D test).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# the same (N, D_total) agent-stacked flattening the comm-policy layer
# applies to broadcasts — one layout, shared by kernel and policy
from repro.core.comm import flatten_agents, unflatten_agents
from repro.kernels.coke_update.coke_update import coke_fused_update


def coke_update_pytree(params, theta_hat, gamma, grads, left, right, *,
                       rho: float, deg: float = 2.0,
                       interpret: bool | None = None):
    """Agent-stacked pytrees -> (g_aug pytree fp32, xi_norm (N,)).

    xi_norm = sqrt of the kernel's xi_sq = ||theta_hat - theta|| per
    agent — censor-decision ready.
    """
    th, leaves = flatten_agents(params)
    hat, _ = flatten_agents(theta_hat)
    gm, _ = flatten_agents(gamma)
    g, _ = flatten_agents(grads)
    lf, _ = flatten_agents(left)
    rt, _ = flatten_agents(right)
    gaug, xisq = coke_fused_update(th, hat, gm, g, lf, rt, rho=rho, deg=deg,
                                   interpret=interpret)
    return (unflatten_agents(gaug, leaves, jax.tree.structure(params)),
            jnp.sqrt(xisq))
