"""Public entry: pytree-level fused COKE update.

Flattens an agent-stacked param pytree to (N, D), runs the fused kernel,
and unflattens g_aug — the drop-in accelerated core for
repro.distributed.consensus.consensus_update on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.coke_update.coke_update import coke_fused_update


def _flatten_stacked(tree):
    leaves = jax.tree.leaves(tree)
    N = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(N, -1).astype(jnp.float32) for l in leaves], axis=1)
    return flat, leaves


def _unflatten_like(flat, leaves):
    out, off = [], 0
    N = leaves[0].shape[0]
    for l in leaves:
        size = l.size // N
        out.append(flat[:, off:off + size].reshape(l.shape))
        off += size
    return out


def coke_update_pytree(params, theta_hat, gamma, grads, left, right, *,
                       rho: float, deg: float = 2.0, interpret: bool = True):
    """Agent-stacked pytrees -> (g_aug pytree fp32, xi_norm (N,))."""
    th, leaves = _flatten_stacked(params)
    hat, _ = _flatten_stacked(theta_hat)
    gm, _ = _flatten_stacked(gamma)
    g, _ = _flatten_stacked(grads)
    lf, _ = _flatten_stacked(left)
    rt, _ = _flatten_stacked(right)
    gaug, xisq = coke_fused_update(th, hat, gm, g, lf, rt, rho=rho, deg=deg,
                                   interpret=interpret)
    gaug_leaves = _unflatten_like(gaug, leaves)
    treedef = jax.tree.structure(params)
    return (jax.tree_util.tree_unflatten(treedef, gaug_leaves),
            jnp.sqrt(xisq))
