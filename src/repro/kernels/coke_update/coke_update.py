"""Pallas TPU kernel: fused COKE consensus update (the Alg.-2 inner loop).

Per agent and per parameter block, in ONE VMEM pass over six streams:

    g_aug  = g + 2 rho deg theta + gamma - rho (deg theta_hat + left + right)
    xi_sq  = partial sums of (theta_hat - theta_new_candidate)^2

The naive XLA program reads/writes each O(P) operand in separate HBM passes
(7+ passes); the fused pass is strictly bandwidth-bound at 6 reads + 2
writes — the per-iteration hot spot of COKE-DP on large parameter vectors.
The censor *decision* needs the full-parameter norm, so the kernel emits
per-block partial sums that the (cheap) host-side jnp finishes with a sum +
compare; the masked broadcast is then a single elementwise select.

Layout: operands flattened to (N_agents, D); grid (N, D/bd); all tiles
(1, bd) VMEM-resident, bd lane-aligned (multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coke_kernel(theta_ref, hat_ref, gamma_ref, grad_ref, left_ref,
                 right_ref, gaug_ref, xisq_ref, *, rho: float, deg: float):
    th = theta_ref[...].astype(jnp.float32)
    hat = hat_ref[...].astype(jnp.float32)
    g = grad_ref[...].astype(jnp.float32)
    gm = gamma_ref[...].astype(jnp.float32)
    l = left_ref[...].astype(jnp.float32)
    r = right_ref[...].astype(jnp.float32)
    gaug = g + 2.0 * rho * deg * th + gm - rho * (deg * hat + l + r)
    gaug_ref[...] = gaug.astype(gaug_ref.dtype)
    diff = hat - th
    xisq_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("rho", "deg", "block_d",
                                             "interpret"))
def coke_fused_update(theta: jax.Array, theta_hat: jax.Array,
                      gamma: jax.Array, grad: jax.Array, left: jax.Array,
                      right: jax.Array, *, rho: float, deg: float = 2.0,
                      block_d: int = 512, interpret: bool = True):
    """All operands (N, D). Returns (g_aug (N, D) fp32, xi_sq (N,) fp32)."""
    N, D = theta.shape
    bd = min(block_d, D)
    pad = (-D) % bd
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        theta, theta_hat, gamma, grad, left, right = map(
            padf, (theta, theta_hat, gamma, grad, left, right))
    Dp = D + pad
    nblocks = Dp // bd

    gaug, xisq = pl.pallas_call(
        functools.partial(_coke_kernel, rho=rho, deg=deg),
        grid=(N, nblocks),
        in_specs=[pl.BlockSpec((1, bd), lambda i, j: (i, j))] * 6,
        out_specs=[
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Dp), jnp.float32),
            jax.ShapeDtypeStruct((N, nblocks), jnp.float32),
        ],
        interpret=interpret,
    )(theta, theta_hat, gamma, grad, left, right)
    return gaug[:, :D], jnp.sum(xisq, axis=1)
