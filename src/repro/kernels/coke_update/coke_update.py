"""Pallas TPU kernels for the COKE Alg.-2 inner loop.

Two entry points, both bit-pinned against `ref.py`:

`coke_fused_update` — the original fused *consensus combine*: given a
precomputed data gradient, one VMEM pass emits

    g_aug  = g + 2 rho deg theta + gamma - rho (deg theta_hat + left + right)
    xi_sq  = per-block partial sums of (theta_hat - theta)^2

`coke_megastep` — the full-iteration megakernel: one `pallas_call` per
ADMM iteration that fuses the RFF-feature application (phi theta), the
linearized/gradient primal step, the ring neighbor combine, and the
censor-norm partial sums. Per agent, theta / theta_hat / gamma and the
ring-rolled neighbor views stay VMEM-resident across the whole inner
loop over sample blocks (their BlockSpec index is constant in the
sample-grid axis, so Pallas revisits the same block); only the (bt, D)
feature tiles stream from HBM. The output buffer is donated onto theta
via `input_output_aliases`, and block shapes are derived from
`launch/analysis.py`'s `roofline()` helper (see
`megastep_launch_params`).

Grid: (N_agents, T_pad / block_t), sample axis innermost. The gradient
accumulator lives in VMEM scratch; the final sample step applies the
consensus terms and writes theta_new plus the censor partial sum
xi_sq = ||theta_new - theta_hat||^2 (zero padding of both T and D
contributes exactly zero — pinned in tests).

`interpret` defaults to None = resolve via
`repro.kernels.runtime.resolve_interpret` (interpret on CPU, compiled
on TPU/GPU, `$REPRO_PALLAS_INTERPRET` overrides); resolution happens at
trace time.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret
from repro.launch import analysis

# ---------------------------------------------------------------------------
# original fused consensus combine (g_aug + censor partial sums)
# ---------------------------------------------------------------------------


def _coke_kernel(theta_ref, hat_ref, gamma_ref, grad_ref, left_ref,
                 right_ref, gaug_ref, xisq_ref, *, rho: float, deg: float):
    th = theta_ref[...].astype(jnp.float32)
    hat = hat_ref[...].astype(jnp.float32)
    g = grad_ref[...].astype(jnp.float32)
    gm = gamma_ref[...].astype(jnp.float32)
    l = left_ref[...].astype(jnp.float32)
    r = right_ref[...].astype(jnp.float32)
    gaug = g + 2.0 * rho * deg * th + gm - rho * (deg * hat + l + r)
    gaug_ref[...] = gaug.astype(gaug_ref.dtype)
    diff = hat - th
    xisq_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("rho", "deg", "block_d",
                                             "interpret"))
def _coke_fused_update(theta, theta_hat, gamma, grad, left, right, *,
                       rho: float, deg: float, block_d: int,
                       interpret: bool):
    N, D = theta.shape
    bd = min(block_d, D)
    pad = (-D) % bd
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)))
        theta, theta_hat, gamma, grad, left, right = map(
            padf, (theta, theta_hat, gamma, grad, left, right))
    Dp = D + pad
    nblocks = Dp // bd

    gaug, xisq = pl.pallas_call(
        functools.partial(_coke_kernel, rho=rho, deg=deg),
        grid=(N, nblocks),
        in_specs=[pl.BlockSpec((1, bd), lambda i, j: (i, j))] * 6,
        out_specs=[
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Dp), jnp.float32),
            jax.ShapeDtypeStruct((N, nblocks), jnp.float32),
        ],
        interpret=interpret,
    )(theta, theta_hat, gamma, grad, left, right)
    return gaug[:, :D], jnp.sum(xisq, axis=1)


def coke_fused_update(theta: jax.Array, theta_hat: jax.Array,
                      gamma: jax.Array, grad: jax.Array, left: jax.Array,
                      right: jax.Array, *, rho: float, deg: float = 2.0,
                      block_d: int = 512, interpret: bool | None = None):
    """All operands (N, D). Returns (g_aug (N, D) fp32, xi_sq (N,) fp32).

    xi_sq is the *squared* censor norm ||theta_hat - theta||^2 per agent
    (partial-sum friendly); `ops.coke_update_pytree` takes the sqrt.
    """
    return _coke_fused_update(theta, theta_hat, gamma, grad, left, right,
                              rho=rho, deg=deg, block_d=block_d,
                              interpret=resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# full-iteration megakernel
# ---------------------------------------------------------------------------

# VMEM working-set budget for block sizing: ~half of a 16 MiB core so the
# pipeline can double-buffer the streamed feature tiles.
MEGASTEP_VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class MegastepLaunch:
    """Block shapes + roofline estimate for one `coke_megastep` call."""
    block_t: int
    padded_t: int
    padded_d: int
    cost: dict        # {"flops", "bytes accessed"} per call
    roofline: dict    # launch.analysis.roofline() terms


def megastep_launch_params(n_agents: int, n_samples: int, dim: int,
                           n_nbr: int, block_t: int | None = None,
                           vmem_budget: int = MEGASTEP_VMEM_BUDGET
                           ) -> MegastepLaunch:
    """Derive the sample-block size and padded shapes for the megakernel.

    The feature dim is padded to the 128-lane tile; the sample block is
    the largest sublane multiple (of 8, capped at 512) whose streamed
    tiles — double-buffered — fit in `vmem_budget` alongside the
    VMEM-resident per-agent rows (theta, theta_hat, gamma, the 2k rolled
    neighbor views, the donated output, and the gradient scratch). The
    resulting cost dict feeds both `pl.CostEstimate` and
    `launch.analysis.roofline` so the launch carries its own
    compute-vs-memory bound.
    """
    Dp = max(128, ((dim + 127) // 128) * 128)
    resident = (5 + n_nbr) * Dp * 4  # theta/hat/gamma/nbrs/out rows + scratch
    if block_t is None:
        bt = 8
        for cand in range(512, 7, -8):
            if 2 * (cand * Dp * 4 + cand * 4) + resident <= vmem_budget:
                bt = cand
                break
        bt = min(bt, ((max(n_samples, 1) + 7) // 8) * 8)
    else:
        bt = block_t
    Tp = ((max(n_samples, 1) + bt - 1) // bt) * bt
    flops = float(n_agents) * (4.0 * Tp * Dp + 12.0 * Dp)
    bytes_accessed = 4.0 * n_agents * (
        Tp * Dp + Tp + (4 + n_nbr) * Dp + 1)
    cost = {"flops": flops, "bytes accessed": bytes_accessed}
    return MegastepLaunch(block_t=bt, padded_t=Tp, padded_d=Dp, cost=cost,
                          roofline=analysis.roofline(cost, {}))


def megastep_scalars(*, rho: float, lam: float, lr: float, n_agents: int,
                     n_samples: int, n_offsets: int):
    """Python-float scalar constants shared by kernel and bit reference."""
    deg = 2.0 * n_offsets
    return {
        "rho": float(rho),
        "deg": deg,
        "lam2": 2.0 * float(lam) / float(n_agents),
        "rho2deg": 2.0 * float(rho) * deg,
        "lr": float(lr),
        "inv_t2": 2.0 / float(n_samples),
    }


def _megastep_kernel(*refs, n_nbr: int, nt: int, rho: float, deg: float,
                     lam2: float, rho2deg: float, lr: float, inv_t2: float):
    (theta_ref, hat_ref, gamma_ref) = refs[:3]
    nbr_refs = refs[3:3 + n_nbr]
    phi_ref, y_ref, out_ref, xisq_ref, g_scr = refs[3 + n_nbr:]
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        g_scr[...] = jnp.zeros_like(g_scr)

    th = theta_ref[...].astype(jnp.float32)          # (1, Dp), VMEM-resident
    phi = phi_ref[0].astype(jnp.float32)             # (bt, Dp) streamed tile
    r = jnp.dot(phi, th.T, preferred_element_type=jnp.float32)    # (bt, 1)
    resid = r - y_ref[...].astype(jnp.float32).T
    g_scr[...] += jnp.dot(resid.T, phi, preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _finalize():
        hat = hat_ref[...].astype(jnp.float32)
        gm = gamma_ref[...].astype(jnp.float32)
        acc = deg * hat
        for nbr in nbr_refs:
            acc = acc + nbr[...].astype(jnp.float32)
        g_data = inv_t2 * g_scr[...]
        gaug = g_data + lam2 * th + rho2deg * th + gm - rho * acc
        theta_new = th - lr * gaug
        out_ref[...] = theta_new
        d = theta_new - hat
        xisq_ref[0, 0] = jnp.sum(d * d)


@functools.partial(jax.jit, static_argnames=("rho", "lam", "lr", "offsets",
                                             "block_t", "interpret"))
def _coke_megastep(theta, theta_hat, gamma, phi, y, *, rho, lam, lr,
                   offsets, block_t, interpret):
    N, T, D = phi.shape
    n_nbr = 2 * len(offsets)
    lp = megastep_launch_params(N, T, D, n_nbr, block_t)
    bt, Tp, Dp = lp.block_t, lp.padded_t, lp.padded_d
    nt = Tp // bt
    sc = megastep_scalars(rho=rho, lam=lam, lr=lr, n_agents=N, n_samples=T,
                          n_offsets=len(offsets))

    pad_row = lambda a: jnp.pad(a.astype(jnp.float32),
                                ((0, 0), (0, Dp - D)))
    theta, theta_hat, gamma = map(pad_row, (theta, theta_hat, gamma))
    phi = jnp.pad(phi.astype(jnp.float32),
                  ((0, 0), (0, Tp - T), (0, Dp - D)))
    y = jnp.pad(y.astype(jnp.float32), ((0, 0), (0, Tp - T)))

    row_spec = pl.BlockSpec((1, Dp), lambda i, t: (i, 0))
    nbr_specs = []
    for o in offsets:
        nbr_specs.append(
            pl.BlockSpec((1, Dp), lambda i, t, o=o: ((i + o) % N, 0)))
        nbr_specs.append(
            pl.BlockSpec((1, Dp), lambda i, t, o=o: ((i - o) % N, 0)))

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"))
    theta_new, xisq = pl.pallas_call(
        functools.partial(_megastep_kernel, n_nbr=n_nbr, nt=nt, **sc),
        grid=(N, nt),
        in_specs=[row_spec, row_spec, row_spec, *nbr_specs,
                  pl.BlockSpec((1, bt, Dp), lambda i, t: (i, t, 0)),
                  pl.BlockSpec((1, bt), lambda i, t: (i, t))],
        out_specs=[
            pl.BlockSpec((1, Dp), lambda i, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Dp), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, Dp), jnp.float32)],
        input_output_aliases={0: 0},
        cost_estimate=pl.CostEstimate(
            flops=lp.cost["flops"], transcendentals=0,
            bytes_accessed=int(lp.cost["bytes accessed"])),
        interpret=interpret,
        **kwargs,
    )(theta, theta_hat, gamma, *([theta_hat] * n_nbr), phi, y)
    return theta_new[:, :D], xisq[:, 0]


def coke_megastep(theta: jax.Array, theta_hat: jax.Array, gamma: jax.Array,
                  phi: jax.Array, y: jax.Array, *, rho: float, lam: float,
                  lr: float, offsets: tuple[int, ...] = (1,),
                  block_t: int | None = None,
                  interpret: bool | None = None):
    """One fused COKE/DKLA gradient-primal iteration for all agents.

    Args: theta/theta_hat/gamma (N, D); phi (N, T, D) RFF features;
    y (N, T) labels; `offsets` the static ring offsets (neighbors at
    +-o for each o). Computes, per agent i with deg = 2*len(offsets):

        g      = (2/T) phi^T (phi theta - y)          # local LS gradient
        g_aug  = g + (2 lam / N) theta + 2 rho deg theta + gamma
                 - rho (deg theta_hat + sum_o theta_hat[i+-o])
        theta' = theta - lr * g_aug

    Returns (theta_new (N, D) fp32, xi_sq (N,) fp32) where xi_sq is the
    *squared* censor norm ||theta_new - theta_hat||^2 — the innovation
    the censor policy thresholds. Bit-identical to
    `ref.coke_megastep_ref` (same block walk, same accumulation order).
    """
    return _coke_megastep(theta, theta_hat, gamma, phi, y, rho=float(rho),
                          lam=float(lam), lr=float(lr),
                          offsets=tuple(offsets), block_t=block_t,
                          interpret=resolve_interpret(interpret))
