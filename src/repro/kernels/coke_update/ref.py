"""Pure-jnp oracles for the fused COKE kernels.

`coke_update_ref` is the elementwise oracle for the consensus combine.
`coke_megastep_ref` is the *bit-level* reference for the full-iteration
megakernel: it replays the identical padding, (block_t, D_pad) block
walk, and accumulation order as the Pallas grid, so on any backend the
two produce bitwise-equal theta_new and xi_sq. It doubles as the
"unfused StepProgram path" — the stage the fused runner substitutes the
megakernel for — which is what makes full-fit bit-parity pins possible.
"""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.coke_update.coke_update import (megastep_launch_params,
                                                   megastep_scalars)


def coke_update_ref(theta, theta_hat, gamma, grad, left, right, *, rho,
                    deg=2.0):
    """Returns (g_aug (N, D) fp32, xi_sq (N,) fp32) — squared censor norm."""
    f = lambda a: a.astype(jnp.float32)
    gaug = (f(grad) + 2.0 * rho * deg * f(theta) + f(gamma)
            - rho * (deg * f(theta_hat) + f(left) + f(right)))
    xi = f(theta_hat) - f(theta)
    return gaug, jnp.sum(xi * xi, axis=-1)


@functools.partial(jax.jit, static_argnames=("rho", "lam", "lr", "offsets",
                                             "block_t"))
def coke_megastep_ref(theta, theta_hat, gamma, phi, y, *, rho, lam, lr,
                      offsets=(1,), block_t=None):
    """Blockwise unfused reference for `coke_megastep` (same contract).

    Walks the same (block_t, D_pad) tiles in the same order as the
    kernel grid — python loop over agents, fori over sample blocks —
    so results are bitwise-equal to the interpret-mode kernel. Jitted:
    XLA-compiled dots round differently from op-by-op eager dispatch,
    and the bit contract is defined against the compiled program.
    """
    N, T, D = phi.shape
    offsets = tuple(offsets)
    lp = megastep_launch_params(N, T, D, 2 * len(offsets), block_t)
    bt, Tp, Dp = lp.block_t, lp.padded_t, lp.padded_d
    nt = Tp // bt
    sc = megastep_scalars(rho=rho, lam=lam, lr=lr, n_agents=N, n_samples=T,
                          n_offsets=len(offsets))
    f32 = jnp.float32

    pad_row = lambda a: jnp.pad(a.astype(f32), ((0, 0), (0, Dp - D)))
    thp, hatp, gmp = map(pad_row, (theta, theta_hat, gamma))
    phib = jnp.pad(phi.astype(f32),
                   ((0, 0), (0, Tp - T), (0, Dp - D))).reshape(N, nt, bt, Dp)
    yb = jnp.pad(y.astype(f32), ((0, 0), (0, Tp - T))).reshape(N, nt, 1, bt)

    outs, xis = [], []
    for i in range(N):
        th = thp[i:i + 1]

        def body(t, g, i=i, th=th):
            pb = phib[i, t]                                   # (bt, Dp)
            r = jnp.dot(pb, th.T, preferred_element_type=f32)  # (bt, 1)
            resid = r - yb[i, t].T
            return g + jnp.dot(resid.T, pb, preferred_element_type=f32)

        g_scr = jax.lax.fori_loop(0, nt, body, jnp.zeros((1, Dp), f32))
        hat = hatp[i:i + 1]
        gm = gmp[i:i + 1]
        acc = sc["deg"] * hat
        for o in offsets:
            acc = acc + hatp[(i + o) % N:(i + o) % N + 1]
            acc = acc + hatp[(i - o) % N:(i - o) % N + 1]
        g_data = sc["inv_t2"] * g_scr
        gaug = (g_data + sc["lam2"] * th + sc["rho2deg"] * th + gm
                - sc["rho"] * acc)
        theta_new = th - sc["lr"] * gaug
        d = theta_new - hat
        outs.append(theta_new)
        xis.append(jnp.sum(d * d))
    return jnp.concatenate(outs, axis=0)[:, :D], jnp.stack(xis)
