"""Pure-jnp oracle for the fused COKE update."""
import jax.numpy as jnp


def coke_update_ref(theta, theta_hat, gamma, grad, left, right, *, rho,
                    deg=2.0):
    f = lambda a: a.astype(jnp.float32)
    gaug = (f(grad) + 2.0 * rho * deg * f(theta) + f(gamma)
            - rho * (deg * f(theta_hat) + f(left) + f(right)))
    xi = f(theta_hat) - f(theta)
    return gaug, jnp.sum(xi * xi, axis=-1)
