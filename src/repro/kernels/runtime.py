"""Interpret-mode resolution for the Pallas wrappers.

Historically every wrapper hardcoded ``interpret=True`` — correct on the
CPU hosts the test suite runs on, but it meant the fused backend never
ran a *compiled* kernel on an accelerator. The contract is now:

* ``interpret=None`` (the default everywhere) resolves to
  ``jax.default_backend() == "cpu"`` — interpret on CPU, compile on
  TPU/GPU.
* The environment variable ``REPRO_PALLAS_INTERPRET`` overrides the
  backend-derived default (``1/true/yes/on`` or ``0/false/no/off``),
  e.g. to force interpret mode while debugging a kernel on device.
* An explicit ``interpret=True/False`` argument always wins.

Resolution happens at trace time inside each wrapper (``interpret`` is a
static jit argument), so flipping the env var between calls re-traces.
"""
from __future__ import annotations

import os

import jax

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUTHY = {"1", "true", "yes", "on"}
_FALSY = {"0", "false", "no", "off"}


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve a wrapper's ``interpret`` argument to a concrete bool.

    Precedence: explicit argument > ``$REPRO_PALLAS_INTERPRET`` >
    ``jax.default_backend() == "cpu"``.
    """
    if interpret is not None:
        return bool(interpret)
    raw = os.environ.get(_ENV_VAR)
    if raw is not None:
        val = raw.strip().lower()
        if val in _TRUTHY:
            return True
        if val in _FALSY:
            return False
        raise ValueError(
            f"{_ENV_VAR}={raw!r} is not a recognised boolean "
            f"(use one of {sorted(_TRUTHY | _FALSY)})")
    return jax.default_backend() == "cpu"
