"""`ThetaStore` — the on-device paged home of thousands of resident models.

The random-feature construction makes every fitted model a (D,) theta
sharing one featurizer, so "thousands of models resident" is just ONE
(M, D) device array — or (M, D/shards) on a mesh with a "model" axis
(`distributed.sharding.theta_stack_spec`); the slot axis stays replicated
so the scorer's per-request row gather never becomes a collective. The
store manages that array like a page table:

  - slot allocation from a free list, then LRU eviction of unpinned slots
    (eviction pages the model back to the registry via `writeback` iff the
    resident theta is dirty — i.e. newer than any published version);
  - faulting: `ensure(id)` on a miss calls `fault(id) -> (theta, version)`
    (the registry load, wired up by `KernelServer`) and installs the
    result — disk I/O happens on the calling (collector) thread, never
    inside a device call;
  - pinned slots: `pin`/`unpin` refcounts protect in-flight work — an
    eviction never reuses a slot some queued bucket still indexes;
  - atomic snapshots: `lookup_batch(ids)` resolves every id (faulting and
    pinning as it goes, so an id faulted late in the batch cannot evict
    one resolved early) and returns (stack, slots) captured under one
    lock. Because jax arrays are immutable and every write rebinds a
    functionally-updated stack, a snapshot is torn-proof: concurrent
    `put`s (hot-swap publishes) are either entirely visible or entirely
    invisible to it.

Writes go through one jitted `stack.at[slot].set(theta)` with a traced
slot index — installing the millionth model compiles nothing new. The
stack is deliberately NOT donated into that update: in-flight snapshots
keep the old buffer alive, which is exactly the hot-swap atomicity
contract (a copy per fault/publish is the price, and it is off the
scoring hot path).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _write(stack, slot, theta):
    return stack.at[slot].set(theta)


def _write_many(stack, slots, thetas):
    return stack.at[slots].set(thetas)


class ThetaStore:
    """Paged (capacity, D) theta stack with LRU eviction and pinned slots.

    fault     — optional `fault(model_id) -> (theta (D,), version | None)`
                miss handler (KernelServer wires the registry load here).
    writeback — optional `writeback(model_id, theta, version) -> version`
                called when a DIRTY resident model is evicted; without it,
                evicting a dirty model raises rather than silently losing
                the only copy of a refined theta.
    """

    def __init__(self, capacity: int, num_features: int, *,
                 mesh=None, dtype=jnp.float32,
                 fault: Callable | None = None,
                 writeback: Callable | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.num_features = int(num_features)
        self.dtype = dtype
        self.fault = fault
        self.writeback = writeback
        stack = jnp.zeros((self.capacity, self.num_features), dtype)
        if mesh is not None:
            from repro.distributed.sharding import shard_theta_stack
            stack = shard_theta_stack(stack, mesh)
        self._stack = stack
        self._update = jax.jit(_write)
        self._update_many = jax.jit(_write_many)
        self._lock = threading.RLock()
        self._slots: OrderedDict[str, int] = OrderedDict()  # LRU: old → new
        self._free = list(range(self.capacity - 1, -1, -1))
        self._pins: dict[str, int] = {}
        self._dirty: set[str] = set()
        self._versions: dict[str, int | None] = {}
        self._stats = {"hits": 0, "faults": 0, "evictions": 0,
                       "writebacks": 0}

    # ---- introspection ---------------------------------------------------
    @property
    def stack(self):
        """The current (capacity, D) device array. Snapshot it under
        `lookup_batch` when slot indices must stay consistent with it."""
        return self._stack

    def __contains__(self, model_id: str) -> bool:
        with self._lock:
            return model_id in self._slots

    def __len__(self) -> int:
        with self._lock:
            return len(self._slots)

    def resident(self) -> list[str]:
        """Resident ids, least-recently-used first."""
        with self._lock:
            return list(self._slots)

    def version_of(self, model_id: str) -> int | None:
        with self._lock:
            if model_id not in self._slots:
                raise KeyError(f"model {model_id!r} is not resident")
            return self._versions[model_id]

    def stats(self) -> dict:
        with self._lock:
            s = dict(self._stats)
            s["resident"] = len(self._slots)
            s["capacity"] = self.capacity
            s["pinned"] = sum(1 for c in self._pins.values() if c > 0)
        return s

    # ---- pinning ---------------------------------------------------------
    def pin(self, model_id: str) -> None:
        """Protect a resident model's slot from eviction (refcounted)."""
        with self._lock:
            if model_id not in self._slots:
                raise KeyError(f"model {model_id!r} is not resident")
            self._pins[model_id] = self._pins.get(model_id, 0) + 1

    def unpin(self, model_id: str) -> None:
        with self._lock:
            count = self._pins.get(model_id, 0)
            if count <= 0:
                raise RuntimeError(f"model {model_id!r} is not pinned")
            if count == 1:
                self._pins.pop(model_id)
            else:
                self._pins[model_id] = count - 1

    # ---- allocation / paging --------------------------------------------
    def _check_theta(self, theta) -> jax.Array:
        theta = jnp.asarray(theta, self.dtype)
        if theta.shape != (self.num_features,):
            raise ValueError(
                f"theta must be ({self.num_features},), got {theta.shape}")
        return theta

    def _allocate(self) -> int:
        """A free slot, evicting the LRU unpinned model if needed.
        Caller holds the lock."""
        if self._free:
            return self._free.pop()
        for victim in self._slots:  # OrderedDict iterates LRU-first
            if self._pins.get(victim, 0) == 0:
                self._evict_locked(victim)
                return self._free.pop()
        raise RuntimeError(
            f"ThetaStore is full ({self.capacity} slots) and every "
            "resident model is pinned — raise the capacity or reduce the "
            "number of distinct models in flight at once")

    def _evict_locked(self, model_id: str) -> None:
        if model_id in self._dirty:
            if self.writeback is None:
                raise RuntimeError(
                    f"evicting dirty model {model_id!r} would lose its "
                    "only copy — attach a registry writeback or publish "
                    "it first")
            new_v = self.writeback(model_id, self._stack[self._slots[model_id]],
                                   self._versions[model_id])
            self._dirty.discard(model_id)
            self._versions[model_id] = new_v
            self._stats["writebacks"] += 1
        slot = self._slots.pop(model_id)
        self._versions.pop(model_id, None)
        self._free.append(slot)
        self._stats["evictions"] += 1

    def evict(self, model_id: str) -> None:
        """Explicitly page one model out (writeback if dirty)."""
        with self._lock:
            if model_id not in self._slots:
                raise KeyError(f"model {model_id!r} is not resident")
            if self._pins.get(model_id, 0):
                raise RuntimeError(f"model {model_id!r} is pinned")
            self._evict_locked(model_id)

    def put(self, model_id: str, theta, *, version: int | None = None,
            dirty: bool = False) -> int:
        """Install (or hot-swap) one model's theta; returns its slot.

        An existing resident id keeps its slot — the write rebinds the
        stack to a functionally-updated array, so snapshots taken before
        the put keep scoring the old theta (hot-swap atomicity)."""
        theta = self._check_theta(theta)
        with self._lock:
            slot = self._slots.get(model_id)
            if slot is None:
                slot = self._allocate()
                self._slots[model_id] = slot
            self._slots.move_to_end(model_id)
            self._stack = self._update(self._stack,
                                       jnp.asarray(slot, jnp.int32), theta)
            self._versions[model_id] = version
            if dirty:
                self._dirty.add(model_id)
            else:
                self._dirty.discard(model_id)
            return slot

    def put_many(self, ids: list[str], thetas, *,
                 dirty: bool = False) -> list[int]:
        """Bulk install (one device call) — the bench/preload path.
        Preloads default to CLEAN: the caller is assumed to hold them
        elsewhere, so eviction may simply drop them; pass dirty=True for
        thetas whose only copy is the store."""
        thetas = jnp.asarray(thetas, self.dtype)
        if thetas.shape != (len(ids), self.num_features):
            raise ValueError(
                f"expected ({len(ids)}, {self.num_features}) thetas, got "
                f"{thetas.shape}")
        with self._lock:
            slots = []
            for model_id in ids:
                slot = self._slots.get(model_id)
                if slot is None:
                    slot = self._allocate()
                    self._slots[model_id] = slot
                self._slots.move_to_end(model_id)
                self._versions[model_id] = None
                if dirty:
                    self._dirty.add(model_id)
                else:
                    self._dirty.discard(model_id)
                slots.append(slot)
            self._stack = self._update_many(
                self._stack, jnp.asarray(np.asarray(slots, np.int32)),
                thetas)
            return slots

    def ensure(self, model_id: str) -> int:
        """Resident slot of `model_id`, faulting it in on a miss."""
        with self._lock:
            slot = self._slots.get(model_id)
            if slot is not None:
                self._slots.move_to_end(model_id)
                self._stats["hits"] += 1
                return slot
            if self.fault is None:
                raise KeyError(
                    f"model {model_id!r} is not resident and the store has "
                    "no fault handler (registry)")
            theta, version = self.fault(model_id)
            self._stats["faults"] += 1
            return self.put(model_id, theta, version=version, dirty=False)

    def lookup_batch(self, ids: list[str]):
        """Resolve a batch of ids to one consistent (stack, slots) pair.

        Returns (stack_snapshot, slots int32 (len(ids),), errors). For
        each id one of three things holds: resolved (slot >= 0, error
        None); failed (slot -1, errors[i] = the exception — an unknown
        model fails only its own rows, never the batch); or DEFERRED
        (slot -1, error None) — the store ran out of unpinned slots
        because ids resolved earlier in this same batch are pinned, so
        the caller should score the resolved ids and retry the deferred
        ones in a fresh round (their slots free up as soon as this one's
        pins drop). That is how a single flush with more distinct tenants
        than store capacity pages through in several device rounds
        instead of erroring.

        Every successfully-resolved id is pinned while later ids fault,
        so an intra-batch eviction can never reuse a slot this batch
        indexes; the snapshot is taken before unpinning, under the same
        lock as every write, so it is consistent with the returned
        slots."""
        slots = np.full(len(ids), -1, np.int32)
        errors: list[Exception | None] = [None] * len(ids)
        with self._lock:
            pinned: list[str] = []
            try:
                for i, model_id in enumerate(ids):
                    try:
                        slots[i] = self.ensure(model_id)
                    except RuntimeError as e:
                        # capacity pressure: if it is OUR pins crowding the
                        # store, defer (slot -1, no error) — a retry after
                        # this round's pins drop will succeed
                        if not pinned:
                            errors[i] = e
                        continue
                    except Exception as e:  # unknown id, bad shape, ...
                        errors[i] = e
                        continue
                    self.pin(model_id)
                    pinned.append(model_id)
                stack = self._stack
            finally:
                for model_id in pinned:
                    self.unpin(model_id)
        return stack, slots, errors
