"""`ModelRegistry` — the disk-backed catalog behind many-model serving.

One registry directory holds every published `KernelModel` artifact, keyed
by model id and version:

    <root>/<model_id>/v00000001/model.npz          (arrays, repro.ckpt)
    <root>/<model_id>/v00000001/model.model.json   (sidecar)
    <root>/<model_id>/v00000002/...

Each version is exactly one `KernelModel.save` artifact — the same
npz + JSON-sidecar format the single-model deploy path uses, so a registry
entry round-trips bit-identically and any `v*/model` path can also be
loaded directly with `KernelModel.load`. The artifact is stamped with its
(model_id, version) identity on publish.

Publishes are atomic: the artifact is written into a hidden temp directory
and `os.rename`d into its version slot. A reader never sees a torn
version; two concurrent publishers of the same id never clobber each other
— the loser of the rename race retries with the next version number. This
is what lets `KernelServer.publish` hot-swap a refined theta under live
traffic: the registry gains the new version first, then the resident slot
flips, and a crash between the two leaves a fully-valid catalog.

The registry is the backing store `ThetaStore` pages against: faults load
the latest version, dirty evictions publish back.
"""
from __future__ import annotations

import dataclasses
import os
import re
import shutil

from repro.api.model import KernelModel

_ID_RE = re.compile(r"[A-Za-z0-9][A-Za-z0-9._\-]*")
_VERSION_RE = re.compile(r"v(\d{8})")
_ARTIFACT = "model"  # basename of the KernelModel artifact inside a version


def _check_id(model_id: str) -> str:
    if not isinstance(model_id, str) or not _ID_RE.fullmatch(model_id):
        raise ValueError(
            f"invalid model id {model_id!r}: ids are [A-Za-z0-9._-]+ and "
            "may not start with '.' (reserved for temp dirs)")
    return model_id


class ModelRegistry:
    """Versioned catalog of `KernelModel` artifacts under one root dir."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    # ---- paths -----------------------------------------------------------
    def _model_dir(self, model_id: str) -> str:
        return os.path.join(self.root, _check_id(model_id))

    def _version_dir(self, model_id: str, version: int) -> str:
        return os.path.join(self._model_dir(model_id), f"v{version:08d}")

    def artifact_path(self, model_id: str, version: int) -> str:
        """The `KernelModel.save`/`load` path of one published version."""
        return os.path.join(self._version_dir(model_id, version), _ARTIFACT)

    # ---- catalog ---------------------------------------------------------
    def models(self) -> list[str]:
        """All model ids with at least one published version, sorted."""
        try:
            entries = sorted(os.listdir(self.root))
        except FileNotFoundError:
            return []
        return [e for e in entries
                if _ID_RE.fullmatch(e) and self.versions(e)]

    def versions(self, model_id: str) -> list[int]:
        """Published versions of one model, ascending ([] if unknown)."""
        try:
            entries = os.listdir(self._model_dir(model_id))
        except FileNotFoundError:
            return []
        out = []
        for e in entries:
            m = _VERSION_RE.fullmatch(e)
            # a version exists iff its sidecar does — a temp dir mid-rename
            # or a half-deleted version never shows up in the catalog
            if m and os.path.exists(os.path.join(
                    self._model_dir(model_id), e, _ARTIFACT + ".model.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_version(self, model_id: str) -> int | None:
        vs = self.versions(model_id)
        return vs[-1] if vs else None

    def __contains__(self, model_id: str) -> bool:
        return bool(self.versions(model_id))

    def __len__(self) -> int:
        return len(self.models())

    # ---- publish / load --------------------------------------------------
    def publish(self, model_id: str, model: KernelModel, *,
                version: int | None = None) -> int:
        """Write one new version of `model_id` atomically; returns the
        version number. With `version=None` (the norm) the next free
        version is taken, retrying past concurrent publishers; an explicit
        `version` raises ValueError if that slot is already taken."""
        base = self._model_dir(model_id)
        os.makedirs(base, exist_ok=True)
        attempt = 0
        while True:
            v = version if version is not None \
                else (self.latest_version(model_id) or 0) + 1 + attempt
            final = self._version_dir(model_id, v)
            if os.path.exists(final):
                if version is not None:
                    raise ValueError(
                        f"{model_id} v{v} is already published; versions "
                        "are immutable — publish a new one")
                attempt += 1
                continue
            tmp = os.path.join(base, f".tmp-v{v:08d}-{os.getpid()}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            stamped = dataclasses.replace(model, model_id=model_id,
                                          version=v)
            stamped.save(os.path.join(tmp, _ARTIFACT))
            try:
                os.rename(tmp, final)  # atomic claim of the version slot
            except OSError:
                shutil.rmtree(tmp, ignore_errors=True)
                if version is not None:
                    raise ValueError(
                        f"{model_id} v{v} was published concurrently; "
                        "versions are immutable — publish a new one")
                attempt += 1
                continue
            return v

    def load(self, model_id: str, version: int | None = None) -> KernelModel:
        """Load one version (latest by default), bit-identical to what was
        published. Raises KeyError for an unknown id/version."""
        _check_id(model_id)
        if version is None:
            version = self.latest_version(model_id)
            if version is None:
                raise KeyError(
                    f"model {model_id!r} is not in the registry at "
                    f"{self.root!r}")
        path = self.artifact_path(model_id, version)
        if not os.path.exists(path + ".model.json"):
            raise KeyError(
                f"model {model_id!r} has no version {version} "
                f"(published: {self.versions(model_id) or 'none'})")
        return KernelModel.load(path)
