"""`KernelServer` — microbatched scoring for `KernelModel` artifacts,
single-tenant or many-model.

Sibling to the LLM `Engine`: where the Engine amortizes decode steps over a
batch of sequences, the KernelServer amortizes RFF scoring over concurrent
requests. Callers `submit()` arbitrarily-sized query batches from any
thread; a collector thread coalesces everything waiting (until `max_batch`
rows are in hand or `max_delay_ms` passes), slices the merged batch into
largest-bucket-sized pieces and pads each piece to a bucketed shape — every
device call is one of the |buckets| compiled shapes, so the jitted scorer
never retraces on ragged traffic however the batch landed — scores them
sharded over the mesh's data axes via `distributed.sharding`-style
NamedShardings, and scatters the rows back to each request's future.

Two tenancy modes share that machinery:

  - **single-tenant** (`KernelServer(model)`): one frozen `KernelModel`,
    scored as `featurize(x) @ theta` — bit-identical to what this server
    always did.
  - **multi-tenant** (`KernelServer(registry=...)` and/or `store=...`):
    requests are tagged with a model id (`submit(x, model_id="user-42")`).
    The collector resolves each id to a slot of the `ThetaStore`'s one
    resident (M, D) stack — faulting misses in from the `ModelRegistry`
    off the device-call path — and the SAME bucket-padded jitted scorer
    featurizes once and gathers each row's theta for a batched per-row
    matvec (`einsum('bd,bd->b', phi, stack[slots])`). No per-model device
    calls; installing model one million compiles nothing new (the stack
    shape is static). `publish()` hot-swaps a refined theta atomically:
    registry first, then the resident slot — in-flight buckets hold an
    immutable snapshot of the old stack, so no request ever scores a torn
    theta.

This is the "serve heavy traffic from millions of users" path the
random-feature construction makes cheap: every user's whole model is one
(D,) theta against a SHARED featurizer, and scoring a mixed batch is one
matmul + cosine + gathered row-dot, data-parallel in the batch dimension
with zero cross-request state.

    server = KernelServer(registry=ModelRegistry("models/"))
    fut = server.submit(x, model_id="user-42")    # (b, d) -> Future[(b,)]
    y = fut.result()
    server.publish("user-42", refined_model)      # hot-swap, no restart
    server.stop()
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.api.model import PREDICT_BACKENDS, KernelModel
from repro.distributed.sharding import batch_specs, theta_stack_spec
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.serve.theta_store import ThetaStore

_STOP = object()
_DEFAULT_ID = "default"


@dataclasses.dataclass(frozen=True)
class KernelServeConfig:
    """Microbatching policy for the scoring server."""

    max_batch: int = 1024            # rows per device call
    max_delay_ms: float = 2.0        # collector wait for co-batchable work
    buckets: tuple[int, ...] = (32, 128, 512, 1024)  # padded batch shapes
    backend: str = "ref"             # "ref" | "fused" (Pallas featurizer)

    def __post_init__(self):
        if self.backend not in PREDICT_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; choose from "
                f"{PREDICT_BACKENDS}")
        if not self.buckets or tuple(sorted(self.buckets)) != self.buckets:
            raise ValueError("buckets must be a non-empty ascending tuple")


@dataclasses.dataclass
class _Request:
    x: np.ndarray                    # (b, d)
    future: Future
    model_id: str | None = None      # None = the server's default model


class KernelServer:
    """Thread-safe microbatching front-end over one jitted scoring call."""

    def __init__(self, model: KernelModel | None = None,
                 config: KernelServeConfig | None = None,
                 mesh=None, *, registry=None, store: ThetaStore | None = None,
                 store_capacity: int = 1024, autostart: bool = True):
        self.cfg = config or KernelServeConfig()
        self.mesh = make_host_mesh() if mesh is None else mesh
        self.registry = registry
        self.multi_tenant = registry is not None or store is not None
        ba = batch_axes(self.mesh)
        self._extent = (math.prod(self.mesh.shape[a] for a in ba)
                        if ba else 1)
        # every padded shape must divide over the data axes
        self._buckets = tuple(-(-b // self._extent) * self._extent
                              for b in self.cfg.buckets)
        self._max_batch = -(-self.cfg.max_batch // self._extent) \
            * self._extent

        # the template model defines the one featurizer every tenant
        # shares (the common-seed RFF premise): an explicit model wins,
        # else the registry's first catalogued model
        if model is None:
            if registry is None:
                raise ValueError(
                    "KernelServer needs a model, or a registry to take "
                    "its featurizer template from")
            ids = registry.models()
            if not ids:
                raise ValueError(
                    "the registry is empty — pass model= so the server "
                    "knows its featurizer (input_dim / D / RFF draw)")
            model = registry.load(ids[0])
        self.model = model

        # eager backend/mapping validation at construction, through the one
        # routing point all scoring paths share
        model.featurize(jnp.zeros((1, model.input_dim), jnp.float32),
                        self.cfg.backend)

        probe = self._buckets[-1]
        x_spec, y_spec = batch_specs(None, (
            jax.ShapeDtypeStruct((probe, model.input_dim), jnp.float32),
            jax.ShapeDtypeStruct((probe,), jnp.float32)), self.mesh)
        x_sh = NamedSharding(self.mesh, x_spec)
        y_sh = NamedSharding(self.mesh, y_spec)

        if self.multi_tenant:
            self.store = store if store is not None else ThetaStore(
                store_capacity, model.num_features, mesh=self.mesh)
            if self.store.num_features != model.num_features:
                raise ValueError(
                    f"store is sized for D={self.store.num_features} but "
                    f"the featurizer produces D={model.num_features}")
            if registry is not None:
                if self.store.fault is None:
                    self.store.fault = self._fault
                if self.store.writeback is None:
                    self.store.writeback = self._writeback
            self._default_id = model.model_id or _DEFAULT_ID
            self.store.put(self._default_id, model.theta,
                           version=model.version,
                           dirty=model.version is None)
            stack_sh = NamedSharding(self.mesh, theta_stack_spec(
                (self.store.capacity, model.num_features), self.mesh))
            (slot_spec,) = batch_specs(
                None, (jax.ShapeDtypeStruct((probe,), jnp.int32),),
                self.mesh)
            backend = self.cfg.backend

            def score_multi(stack, x, slots):
                # one featurize for the whole mixed bucket, then a batched
                # per-row matvec against each row's gathered theta slot —
                # the formulation `KernelModel.score_rows` pins bit-level
                phi = model.featurize(x, backend)
                return jnp.einsum("bd,bd->b", phi, stack[slots])

            self._score_multi = jax.jit(
                score_multi,
                in_shardings=(stack_sh, x_sh,
                              NamedSharding(self.mesh, slot_spec)),
                out_shardings=y_sh)
        else:
            self.store = None
            self._default_id = model.model_id
            theta = model.theta

            def score(x):
                return model.featurize(x, self.cfg.backend) @ theta

            # batch-dim data parallelism from the repo's one sharding
            # rule-set: queries and predictions shard their leading dim
            # over the batch axes
            self._score = jax.jit(score, in_shardings=x_sh,
                                  out_shardings=y_sh)

        self._queue: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._stats = {"requests": 0, "rows": 0, "batches": 0,
                       "padded_rows": 0}
        self._worker: threading.Thread | None = None
        self._stopped = False
        if autostart:
            self.start()

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> None:
        if self._worker is not None:
            return
        self._stopped = False
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="kernel-server")
        self._worker.start()

    def stop(self) -> None:
        """Drain outstanding requests, then stop the collector thread."""
        with self._lock:
            # same lock as submit(): every request that passed the _stopped
            # check is on the queue before the sentinel, so none is lost
            if self._stopped:
                return
            self._stopped = True
            self._queue.put(_STOP)
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._drain_inline()

    def _drain_inline(self) -> None:
        """Score anything still queued (requests enqueued while the worker
        was shutting down, or with no worker ever started)."""
        leftover = []
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                leftover.append(item)
        if leftover:
            self._flush(leftover)

    def __enter__(self) -> "KernelServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- many-model management -------------------------------------------
    def _check_compatible(self, other: KernelModel, model_id: str) -> None:
        """Every tenant must share the template's featurizer — that is what
        lets a mixed bucket featurize once."""
        tpl = self.model
        if (other.input_dim != tpl.input_dim
                or other.num_features != tpl.num_features
                or other.rff_params.mapping != tpl.rff_params.mapping
                or not np.array_equal(np.asarray(other.rff_params.omega),
                                      np.asarray(tpl.rff_params.omega))
                or not np.array_equal(np.asarray(other.rff_params.bias),
                                      np.asarray(tpl.rff_params.bias))):
            raise ValueError(
                f"model {model_id!r} was fitted against a different RFF "
                "featurizer than this server's template — many-model "
                "serving shares ONE common-seed feature map; refit with "
                "the shared draw or serve it from its own server")

    def _fault(self, model_id: str):
        """ThetaStore miss handler: load the latest registry version on
        the collector thread — never inside a device call."""
        loaded = self.registry.load(model_id)  # KeyError if unknown
        self._check_compatible(loaded, model_id)
        return loaded.theta, loaded.version

    def _writeback(self, model_id: str, theta, version):
        """ThetaStore dirty-eviction handler: page the refined theta back
        into the registry as a fresh version."""
        art = dataclasses.replace(
            self.model, theta=jnp.asarray(theta), thetas=None,
            meta={**self.model.meta, "published_via": "ThetaStore.evict"})
        return self.registry.publish(model_id, art)

    def publish(self, model_id: str, model) -> int | None:
        """Hot-swap one tenant's parameters under live traffic.

        `model` is a refined `KernelModel` (e.g. from `partial_fit`) or a
        bare (D,) theta. The registry gains the new version FIRST, then
        the resident slot flips — in-flight buckets finish on their
        immutable snapshot of the old stack, every later bucket sees the
        new theta, and a crash in between leaves a valid catalog whose
        next fault serves the new version. Returns the published version
        (None when the server has no registry: the theta becomes resident
        and dirty, to be written back on eviction)."""
        if not self.multi_tenant:
            raise RuntimeError(
                "publish() needs a multi-tenant server — construct with "
                "registry= and/or store=")
        if isinstance(model, KernelModel):
            self._check_compatible(model, model_id)
            theta = model.theta
            art = model
        else:
            theta = jnp.asarray(model, jnp.float32)
            art = dataclasses.replace(
                self.model, theta=theta, thetas=None,
                meta={**self.model.meta,
                      "published_via": "KernelServer.publish"})
        if self.registry is not None:
            version = self.registry.publish(model_id, art)
            self.store.put(model_id, theta, version=version, dirty=False)
            return version
        self.store.put(model_id, theta, dirty=True)
        return None

    # ---- request path ----------------------------------------------------
    def submit(self, x, model_id: str | None = None) -> Future:
        """Enqueue a query batch; resolves to (b,) predictions ((,) for a
        bare (d,) vector). `model_id` tags the request with the tenant to
        score against (multi-tenant servers; defaults to the server's
        default model when it has one)."""
        x = np.asarray(x, np.float32)
        scalar = x.ndim == 1
        if scalar:
            x = x[None]
        if x.ndim != 2 or x.shape[-1] != self.model.input_dim:
            raise ValueError(
                f"expected (b, {self.model.input_dim}) queries, got "
                f"{x.shape}")
        if model_id is None:
            model_id = self._default_id
            if self.multi_tenant and model_id is None:
                raise ValueError(
                    "this multi-tenant server has no default model — tag "
                    "the request: submit(x, model_id=...)")
        elif not self.multi_tenant and model_id != self._default_id:
            raise ValueError(
                f"this server serves only {self._default_id or 'its one'!s} "
                f"model, not {model_id!r} — construct with registry=/store= "
                "for many-model serving")
        fut: Future = Future()
        if scalar:
            inner, fut = fut, Future()
            inner.add_done_callback(
                lambda f: fut.set_exception(f.exception())
                if f.exception() else fut.set_result(f.result()[0]))
            req = _Request(x, inner, model_id)
        else:
            req = _Request(x, fut, model_id)
        with self._lock:
            # check-and-enqueue under the stop() lock: either this request
            # lands on the queue ahead of the _STOP sentinel, or it raises
            if self._stopped:
                raise RuntimeError("KernelServer is stopped")
            self._queue.put(req)
            self._stats["requests"] += 1
        return fut

    def predict(self, x, model_id: str | None = None) -> np.ndarray:
        """Synchronous convenience wrapper around submit()."""
        return self.submit(x, model_id).result()

    def stats(self) -> dict:
        with self._lock:
            s = dict(self._stats)
        s["mean_rows_per_batch"] = (s["rows"] / s["batches"]
                                    if s["batches"] else 0.0)
        if self.store is not None:
            s["store"] = self.store.stats()
        return s

    # ---- collector -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is _STOP:
                return
            batch = [item]
            rows = item.x.shape[0]
            deadline = time.monotonic() + self.cfg.max_delay_ms / 1e3
            while rows < self._max_batch:
                timeout = deadline - time.monotonic()
                try:
                    nxt = (self._queue.get_nowait() if timeout <= 0
                           else self._queue.get(timeout=timeout))
                except queue.Empty:
                    break
                if nxt is _STOP:
                    self._flush(batch)
                    return
                batch.append(nxt)
                rows += nxt.x.shape[0]
            self._flush(batch)

    def _pad_to_bucket(self, n: int) -> int:
        """Smallest bucket holding n rows. Only defined up to the largest
        bucket — `_flush` slices oversize batches into bucket-shaped device
        calls first, so every compiled shape is one of the |buckets|
        bucketed ones and the jitted scorer NEVER retraces on ragged
        traffic (the contract tests/test_kernel_server.py pins)."""
        for b in self._buckets:
            if n <= b:
                return b
        raise AssertionError(
            f"_pad_to_bucket({n}) beyond the largest bucket "
            f"{self._buckets[-1]} — oversize flushes must be sliced first")

    def _score_padded(self, xs: np.ndarray) -> tuple[np.ndarray, int]:
        """One bucket-shaped device call: pad n <= max-bucket rows up to
        their bucket, score, strip the padding. Returns (preds, pad rows);
        the caller commits stats only once the WHOLE flush scored — a
        failing later slice must not leave stats counting rows no caller
        ever received."""
        n = xs.shape[0]
        padded = self._pad_to_bucket(n)
        if padded != n:
            xs = np.concatenate(
                [xs, np.zeros((padded - n, xs.shape[1]), xs.dtype)])
        preds = np.asarray(jax.device_get(self._score(jnp.asarray(xs))))
        return preds[:n], padded - n

    def _score_padded_multi(self, stack, xs: np.ndarray,
                            slots: np.ndarray) -> tuple[np.ndarray, int]:
        """The multi-tenant twin of `_score_padded`: pads rows AND slot
        ids (padding gathers slot 0 — always a valid row of the stack —
        and its results are stripped)."""
        n = xs.shape[0]
        padded = self._pad_to_bucket(n)
        if padded != n:
            xs = np.concatenate(
                [xs, np.zeros((padded - n, xs.shape[1]), xs.dtype)])
            slots = np.concatenate(
                [slots, np.zeros(padded - n, slots.dtype)])
        preds = np.asarray(jax.device_get(self._score_multi(
            stack, jnp.asarray(xs), jnp.asarray(slots))))
        return preds[:n], padded - n

    def _flush(self, batch: list[_Request]) -> None:
        if not self.multi_tenant:
            self._score_and_scatter(batch)
            return
        # Resolve every request's model id to a theta slot (faulting
        # misses in from the registry) and snapshot ONE consistent stack
        # per round. A request whose id cannot be resolved fails alone;
        # requests DEFERRED under capacity pressure (more distinct models
        # waiting than unpinned slots) page through in follow-up rounds
        # once the current round's slots free up.
        remaining = batch
        while remaining:
            stack, req_slots, errors = self.store.lookup_batch(
                [r.model_id for r in remaining])
            kept, deferred = [], []
            for r, slot, err in zip(remaining, req_slots, errors):
                if err is not None:
                    r.future.set_exception(err)
                elif slot < 0:
                    deferred.append(r)
                else:
                    kept.append((r, slot))
            if kept:
                slots = np.concatenate(
                    [np.full(r.x.shape[0], slot, np.int32)
                     for r, slot in kept])
                self._score_and_scatter([r for r, _ in kept], stack, slots)
            elif deferred:
                # no progress is possible — every slot is pinned by work
                # outside this flush; fail rather than spin
                err = RuntimeError(
                    "ThetaStore has no unpinned slot for any waiting "
                    "model — raise the store capacity")
                for r in deferred:
                    r.future.set_exception(err)
                return
            remaining = deferred

    def _score_and_scatter(self, batch: list[_Request], stack=None,
                           slots: np.ndarray | None = None) -> None:
        # The collector coalesces until rows >= max_batch, so the LAST
        # request can overshoot; and a single submit() may exceed max_batch
        # outright. Slice the merged batch into largest-bucket-sized device
        # calls instead of padding past the bucket table — an over-max call
        # would compile a fresh shape per ragged size.
        xs = np.concatenate([r.x for r in batch])
        n = xs.shape[0]
        cap = self._buckets[-1]
        try:
            if stack is not None:
                scored = [self._score_padded_multi(stack, xs[off:off + cap],
                                                   slots[off:off + cap])
                          for off in range(0, n, cap)]
            else:
                scored = [self._score_padded(xs[off:off + cap])
                          for off in range(0, n, cap)]
        except Exception as e:  # scoring failed: fail every caller, keep serving
            for r in batch:
                r.future.set_exception(e)
            return
        preds = np.concatenate([p for p, _ in scored])
        with self._lock:
            self._stats["batches"] += len(scored)
            self._stats["rows"] += n
            self._stats["padded_rows"] += sum(pad for _, pad in scored)
        off = 0
        for r in batch:
            b = r.x.shape[0]
            r.future.set_result(preds[off:off + b])
            off += b
