"""`KernelServer` — microbatched scoring for `KernelModel` artifacts.

Sibling to the LLM `Engine`: where the Engine amortizes decode steps over a
batch of sequences, the KernelServer amortizes RFF scoring over concurrent
requests. Callers `submit()` arbitrarily-sized query batches from any
thread; a collector thread coalesces everything waiting (until `max_batch`
rows are in hand or `max_delay_ms` passes), slices the merged batch into
largest-bucket-sized pieces and pads each piece to a bucketed shape — every
device call is one of the |buckets| compiled shapes, so the jitted scorer
never retraces on ragged traffic however the batch landed — scores them
sharded over the mesh's data axes via `distributed.sharding`-style
NamedShardings, and scatters the rows back to each request's future.

This is the "serve heavy traffic" path the random-feature construction
makes cheap: the whole model is (omega, bias, theta) — a few hundred KB —
and scoring is one matmul + cosine + matvec, data-parallel in the batch
dimension with zero cross-request state.

    server = KernelServer(model)                  # host mesh by default
    fut = server.submit(x)                        # (b, d) -> Future[(b,)]
    y = fut.result()
    server.stop()
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.api.model import PREDICT_BACKENDS, KernelModel
from repro.distributed.sharding import batch_specs
from repro.launch.mesh import batch_axes, make_host_mesh

_STOP = object()


@dataclasses.dataclass(frozen=True)
class KernelServeConfig:
    """Microbatching policy for the scoring server."""

    max_batch: int = 1024            # rows per device call
    max_delay_ms: float = 2.0        # collector wait for co-batchable work
    buckets: tuple[int, ...] = (32, 128, 512, 1024)  # padded batch shapes
    backend: str = "ref"             # "ref" | "fused" (Pallas featurizer)

    def __post_init__(self):
        if self.backend not in PREDICT_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; choose from "
                f"{PREDICT_BACKENDS}")
        if not self.buckets or tuple(sorted(self.buckets)) != self.buckets:
            raise ValueError("buckets must be a non-empty ascending tuple")


@dataclasses.dataclass
class _Request:
    x: np.ndarray                    # (b, d)
    future: Future


class KernelServer:
    """Thread-safe microbatching front-end over one jitted scoring call."""

    def __init__(self, model: KernelModel,
                 config: KernelServeConfig | None = None,
                 mesh=None, *, autostart: bool = True):
        self.model = model
        self.cfg = config or KernelServeConfig()
        self.mesh = make_host_mesh() if mesh is None else mesh
        ba = batch_axes(self.mesh)
        self._extent = (math.prod(self.mesh.shape[a] for a in ba)
                        if ba else 1)
        # every padded shape must divide over the data axes
        self._buckets = tuple(-(-b // self._extent) * self._extent
                              for b in self.cfg.buckets)
        self._max_batch = -(-self.cfg.max_batch // self._extent) \
            * self._extent

        # eager backend/mapping validation at construction, through the one
        # routing point all scoring paths share
        model.featurize(jnp.zeros((1, model.input_dim), jnp.float32),
                        self.cfg.backend)
        theta = model.theta

        def score(x):
            return model.featurize(x, self.cfg.backend) @ theta

        # batch-dim data parallelism from the repo's one sharding rule-set:
        # queries and predictions shard their leading dim over the batch axes
        probe = self._buckets[-1]
        x_spec, y_spec = batch_specs(None, (
            jax.ShapeDtypeStruct((probe, model.input_dim), jnp.float32),
            jax.ShapeDtypeStruct((probe,), jnp.float32)), self.mesh)
        self._score = jax.jit(
            score, in_shardings=NamedSharding(self.mesh, x_spec),
            out_shardings=NamedSharding(self.mesh, y_spec))

        self._queue: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._stats = {"requests": 0, "rows": 0, "batches": 0,
                       "padded_rows": 0}
        self._worker: threading.Thread | None = None
        self._stopped = False
        if autostart:
            self.start()

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> None:
        if self._worker is not None:
            return
        self._stopped = False
        self._worker = threading.Thread(target=self._loop, daemon=True,
                                        name="kernel-server")
        self._worker.start()

    def stop(self) -> None:
        """Drain outstanding requests, then stop the collector thread."""
        with self._lock:
            # same lock as submit(): every request that passed the _stopped
            # check is on the queue before the sentinel, so none is lost
            if self._stopped:
                return
            self._stopped = True
            self._queue.put(_STOP)
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._drain_inline()

    def _drain_inline(self) -> None:
        """Score anything still queued (requests enqueued while the worker
        was shutting down, or with no worker ever started)."""
        leftover = []
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                leftover.append(item)
        if leftover:
            self._flush(leftover)

    def __enter__(self) -> "KernelServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ---- request path ----------------------------------------------------
    def submit(self, x) -> Future:
        """Enqueue a query batch; resolves to (b,) predictions ((,) for a
        bare (d,) vector)."""
        x = np.asarray(x, np.float32)
        scalar = x.ndim == 1
        if scalar:
            x = x[None]
        if x.ndim != 2 or x.shape[-1] != self.model.input_dim:
            raise ValueError(
                f"expected (b, {self.model.input_dim}) queries, got "
                f"{x.shape}")
        fut: Future = Future()
        if scalar:
            inner, fut = fut, Future()
            inner.add_done_callback(
                lambda f: fut.set_exception(f.exception())
                if f.exception() else fut.set_result(f.result()[0]))
            req = _Request(x, inner)
        else:
            req = _Request(x, fut)
        with self._lock:
            # check-and-enqueue under the stop() lock: either this request
            # lands on the queue ahead of the _STOP sentinel, or it raises
            if self._stopped:
                raise RuntimeError("KernelServer is stopped")
            self._queue.put(req)
            self._stats["requests"] += 1
        return fut

    def predict(self, x) -> np.ndarray:
        """Synchronous convenience wrapper around submit()."""
        return self.submit(x).result()

    def stats(self) -> dict:
        with self._lock:
            s = dict(self._stats)
        s["mean_rows_per_batch"] = (s["rows"] / s["batches"]
                                    if s["batches"] else 0.0)
        return s

    # ---- collector -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is _STOP:
                return
            batch = [item]
            rows = item.x.shape[0]
            deadline = time.monotonic() + self.cfg.max_delay_ms / 1e3
            while rows < self._max_batch:
                timeout = deadline - time.monotonic()
                try:
                    nxt = (self._queue.get_nowait() if timeout <= 0
                           else self._queue.get(timeout=timeout))
                except queue.Empty:
                    break
                if nxt is _STOP:
                    self._flush(batch)
                    return
                batch.append(nxt)
                rows += nxt.x.shape[0]
            self._flush(batch)

    def _pad_to_bucket(self, n: int) -> int:
        """Smallest bucket holding n rows. Only defined up to the largest
        bucket — `_flush` slices oversize batches into bucket-shaped device
        calls first, so every compiled shape is one of the |buckets|
        bucketed ones and the jitted scorer NEVER retraces on ragged
        traffic (the contract tests/test_kernel_server.py pins)."""
        for b in self._buckets:
            if n <= b:
                return b
        raise AssertionError(
            f"_pad_to_bucket({n}) beyond the largest bucket "
            f"{self._buckets[-1]} — oversize flushes must be sliced first")

    def _score_padded(self, xs: np.ndarray) -> tuple[np.ndarray, int]:
        """One bucket-shaped device call: pad n <= max-bucket rows up to
        their bucket, score, strip the padding. Returns (preds, pad rows);
        the caller commits stats only once the WHOLE flush scored — a
        failing later slice must not leave stats counting rows no caller
        ever received."""
        n = xs.shape[0]
        padded = self._pad_to_bucket(n)
        if padded != n:
            xs = np.concatenate(
                [xs, np.zeros((padded - n, xs.shape[1]), xs.dtype)])
        preds = np.asarray(jax.device_get(self._score(jnp.asarray(xs))))
        return preds[:n], padded - n

    def _flush(self, batch: list[_Request]) -> None:
        # The collector coalesces until rows >= max_batch, so the LAST
        # request can overshoot; and a single submit() may exceed max_batch
        # outright. Slice the merged batch into largest-bucket-sized device
        # calls instead of padding past the bucket table — an over-max call
        # would compile a fresh shape per ragged size.
        xs = np.concatenate([r.x for r in batch])
        n = xs.shape[0]
        cap = self._buckets[-1]
        try:
            scored = [self._score_padded(xs[off:off + cap])
                      for off in range(0, n, cap)]
        except Exception as e:  # scoring failed: fail every caller, keep serving
            for r in batch:
                r.future.set_exception(e)
            return
        preds = np.concatenate([p for p, _ in scored])
        with self._lock:
            self._stats["batches"] += len(scored)
            self._stats["rows"] += n
            self._stats["padded_rows"] += sum(pad for _, pad in scored)
        off = 0
        for r in batch:
            b = r.x.shape[0]
            r.future.set_result(preds[off:off + b])
            off += b
