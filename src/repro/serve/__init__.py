from repro.serve.engine import Engine, ServeConfig  # noqa: F401
from repro.serve.kernel_server import (KernelServeConfig,  # noqa: F401
                                       KernelServer)
