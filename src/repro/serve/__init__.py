from repro.serve.engine import Engine, ServeConfig  # noqa: F401
from repro.serve.kernel_server import (KernelServeConfig,  # noqa: F401
                                       KernelServer)
from repro.serve.registry import ModelRegistry  # noqa: F401
from repro.serve.theta_store import ThetaStore  # noqa: F401
