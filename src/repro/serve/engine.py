"""Batched serving engine: prefill once, then decode — greedy argmax or
temperature sampling per `ServeConfig`.

Host-side loop over jit'd prefill / decode_step; the decode step is the same
function the dry-run lowers for `decode_32k` / `long_500k`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.common import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    cache_len: int = 256
    greedy: bool = True              # argmax decode; False = sample
    temperature: float = 1.0         # sampling softmax temperature

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0.0:
            raise ValueError(
                f"sampling requires temperature > 0, got {self.temperature}"
                " (use greedy=True for argmax decoding)")


class Engine:
    """Minimal batched engine. Prompts are pre-tokenized int32 arrays of the
    same length (left-padding is out of scope for this repro)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 extra_batch: dict | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.extra = extra_batch or {}
        self._decode = jax.jit(
            lambda p, t, s, pos: model_lib.decode_step(p, cfg, t, s, pos))

    def _prefill_state(self, prompts: jax.Array):
        """Build decode caches: one fused forward for decoder-only archs
        (model_lib.prefill_with_state); enc-dec fills the cross memory once
        then replays prompt tokens through decode."""
        B, S = prompts.shape
        if not self.cfg.is_encdec:
            logits, state = jax.jit(
                lambda p, b: model_lib.prefill_with_state(
                    p, self.cfg, b, self.scfg.cache_len)
            )(self.params, {"tokens": prompts, **self.extra})
            return logits, state, S

        enc_len = self.extra["encoder_embeds"].shape[1]
        state = model_lib.init_serve_state(
            self.cfg, B, self.scfg.cache_len, enc_len=enc_len)
        state = _fill_cross_memory(self.cfg, self.params, state,
                                   self.extra["encoder_embeds"])
        logits = None
        for t in range(S):
            logits, state = self._decode(self.params, prompts[:, t:t + 1],
                                         state, jnp.asarray(t, jnp.int32))
        return logits, state, S

    def _select(self, logits: jax.Array, key: jax.Array | None) -> jax.Array:
        """Next-token choice from (B, 1, V') logits per the ServeConfig:
        greedy argmax, or temperature-scaled categorical sampling."""
        logits = logits[:, :, :self.cfg.vocab_size]
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature, axis=-1)

    def generate(self, prompts: np.ndarray,
                 key: jax.Array | None = None) -> np.ndarray:
        """Decode max_new_tokens continuations. `key` seeds sampling when
        greedy=False (defaults to PRNGKey(0) for reproducibility); it is
        ignored for greedy decoding."""
        prompts = jnp.asarray(prompts, jnp.int32)
        logits, state, pos = self._prefill_state(prompts)
        if self.scfg.greedy:
            keys = [None] * self.scfg.max_new_tokens
        else:
            if key is None:
                key = jax.random.PRNGKey(0)
            keys = list(jax.random.split(key, self.scfg.max_new_tokens))
        out = []
        token = self._select(logits[:, -1:, :], keys[0])
        out.append(token)
        for i in range(self.scfg.max_new_tokens - 1):
            logits, state = self._decode(self.params, token.astype(jnp.int32),
                                         state, jnp.asarray(pos + i, jnp.int32))
            token = self._select(logits, keys[i + 1])
            out.append(token)
        return np.asarray(jnp.concatenate(out, axis=1))


def _fill_cross_memory(cfg, params, state, encoder_embeds):
    """Encode once and project per-layer cross k/v into the serve state."""
    from repro.models import blocks as blk
    from repro.models.common import rms_norm
    enc_pos = jnp.arange(encoder_embeds.shape[1], dtype=jnp.int32)

    def enc_body(x, lp):
        x, _ = blk.block_forward(lp, cfg, x, enc_pos, "dense", causal=False)
        return x, None

    memory, _ = jax.lax.scan(enc_body, encoder_embeds.astype(cfg.dtype),
                             params["encoder"])
    memory = rms_norm(memory, params["enc_norm"], cfg.norm_eps)

    def proj(lp):
        return blk.cross_memory_kv(lp["cross_attn"], memory)

    ks, vs = jax.vmap(proj)(params["decoder"])
    return dict(state, cross_k=ks, cross_v=vs)
