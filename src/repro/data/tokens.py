"""Synthetic LM token pipeline for the framework side (train/serve drivers).

Deterministic, shardable streams of token batches — each data-parallel agent
(mesh `data` shard) reads a disjoint slice, matching the paper's
locally-observed-data regime. Host-side numpy generation, device upload via
the caller's sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov-ish structure so the loss actually decreases during smoke training
    structure: float = 0.8


class TokenStream:
    """Infinite deterministic stream of (tokens, labels) batches.

    Generates order-1 structured sequences: with prob `structure` the next
    token is (prev * 31 + 7) % vocab (learnable), else uniform noise.
    """

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg

    def batch(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        det = (rng.random((B, S)) < cfg.structure)
        noise = rng.integers(0, V, (B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1].astype(np.int64) * 31 + 7) % V
            toks[:, t] = np.where(det[:, t], nxt, noise[:, t]).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return toks, labels

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def regression_shards_to_device(dataset, rff_params, featurize_fn):
    """Featurize a per-agent `Dataset` into (N, T, D) arrays ready for the
    COKE Problem — used by the kernel-regression driver."""
    import jax.numpy as jnp

    feats = featurize_fn(rff_params, jnp.asarray(dataset.x))
    labels = jnp.asarray(dataset.y)
    return feats, labels
