"""Dataset generators.

`paper_synthetic` reproduces Section 5.1 exactly: N agents, each with
T_i ~ U(4000, 6000) pairs from  y = sum_m b_m kappa(c_m, x) + e,
b_m ~ U[0,1], c_m ~ N(0, I_5), x ~ N(0, I_5), e ~ N(0, 0.1),
Gaussian kernel with bandwidth sigma = 5.

`uci_standin` generates stand-ins for the UCI regression datasets used in
Section 5.2. The container is offline, so the real files are unavailable; the
generators match the published sample counts and input dimensions and produce
a smooth nonlinear regression surface, which preserves the experimental
*protocol* (normalization to [0,1], 70/30 split, per-agent sharding) even
though absolute MSE numbers are not comparable to the paper's tables. This is
recorded in DESIGN.md / EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    """Per-agent sharded regression dataset (equal shards for batching)."""

    x: np.ndarray  # (N, T_i, d) in [0, 1]
    y: np.ndarray  # (N, T_i)
    x_test: np.ndarray  # (N, S_i, d)
    y_test: np.ndarray  # (N, S_i)
    name: str

    @property
    def num_agents(self) -> int:
        return self.x.shape[0]

    @property
    def input_dim(self) -> int:
        return self.x.shape[-1]


def _normalize01(x: np.ndarray) -> np.ndarray:
    lo, hi = x.min(axis=(0, 1), keepdims=True), x.max(axis=(0, 1), keepdims=True)
    return (x - lo) / np.maximum(hi - lo, 1e-9)


def _split(x, y, train_frac=0.7):
    Ti = x.shape[1]
    cut = int(Ti * train_frac)
    return x[:, :cut], y[:, :cut], x[:, cut:], y[:, cut:]


def paper_synthetic(
    num_agents: int = 20,
    samples_per_agent: int = 500,
    input_dim: int = 5,
    num_components: int = 50,
    bandwidth: float = 5.0,
    noise_std: float = np.sqrt(0.1),
    seed: int = 0,
    name: str = "synthetic",
) -> Dataset:
    """The paper's synthetic model (Sec 5.1), equal shards for batching.

    (The paper draws T_i in (4000, 6000); we default to a smaller equal shard
    for test speed — Assumption 3 only requires same order of magnitude.)
    """
    rng = np.random.default_rng(seed)
    b = rng.uniform(0.0, 1.0, num_components)
    c = rng.normal(size=(num_components, input_dim))
    x = rng.normal(size=(num_agents, samples_per_agent, input_dim))

    # y = sum_m b_m exp(-||c_m - x||^2 / (2 sigma^2)) + e
    sq = ((x[:, :, None, :] - c[None, None, :, :]) ** 2).sum(-1)
    y = (np.exp(-sq / (2.0 * bandwidth**2)) @ b
         + rng.normal(scale=noise_std, size=(num_agents, samples_per_agent)))

    x = _normalize01(x)
    # Sec. 5: "entries of data samples are normalized to lie in [0,1]" —
    # label scale determines how censor thresholds bite, so this matters.
    y = (y - y.min()) / max(y.max() - y.min(), 1e-9)
    xtr, ytr, xte, yte = _split(x, y)
    return Dataset(xtr.astype(np.float32), ytr.astype(np.float32),
                   xte.astype(np.float32), yte.astype(np.float32), name)


# ---------------------------------------------------------------------------
# Clustered non-IID: K latent tasks, per-agent mixtures (personalization)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeterogeneousDataset(Dataset):
    """A clustered non-IID `Dataset`: agent n's labels come from latent
    task cluster[n] (plus a small cross-task mixture), so full consensus
    averages models that were never meant to agree. The ground-truth
    cluster assignment ships with the data — it is the reference the
    graph-recovery metric scores learned adjacencies against."""

    cluster: np.ndarray = None   # (N,) int — agent n's latent task
    num_tasks: int = 0


def heterogeneous(
    num_agents: int = 20,
    num_tasks: int = 3,
    samples_per_agent: int = 500,
    input_dim: int = 5,
    num_components: int = 50,
    bandwidth: float = 5.0,
    noise_std: float = np.sqrt(0.1),
    mix: float = 0.1,
    seed: int = 0,
    name: str = "heterogeneous",
) -> HeterogeneousDataset:
    """The paper's synthetic mixture split into K latent tasks.

    All tasks share the component centers c_m (same input geometry), but
    each task t draws its own mixture weights b_t — K distinct target
    functions over a common feature space. Agent n is assigned to task
    cluster[n] = n % K (balanced round-robin) and labels with the softened
    weights  w_n = (1 - mix) b_{cluster[n]} + (mix / K) sum_t b_t : with
    mix > 0 tasks overlap slightly (collaboration helps), with mix = 0
    they are fully disjoint. Inputs stay iid across agents — the
    heterogeneity is in the target function, which is exactly what theta
    affinities can detect. Normalization/split follow paper_synthetic.
    """
    if not 1 <= num_tasks <= num_agents:
        raise ValueError(
            f"need 1 <= num_tasks <= num_agents, got K={num_tasks} over "
            f"N={num_agents} agents")
    rng = np.random.default_rng(seed)
    b = rng.uniform(0.0, 1.0, (num_tasks, num_components))   # per-task
    c = rng.normal(size=(num_components, input_dim))          # shared
    x = rng.normal(size=(num_agents, samples_per_agent, input_dim))

    cluster = np.arange(num_agents) % num_tasks
    onehot = np.eye(num_tasks)[cluster]                       # (N, K)
    alpha = (1.0 - mix) * onehot + mix / num_tasks            # (N, K)
    w = alpha @ b                                             # (N, M)

    sq = ((x[:, :, None, :] - c[None, None, :, :]) ** 2).sum(-1)
    kappa = np.exp(-sq / (2.0 * bandwidth**2))                # (N, T, M)
    y = (np.einsum("ntm,nm->nt", kappa, w)
         + rng.normal(scale=noise_std, size=(num_agents, samples_per_agent)))

    x = _normalize01(x)
    y = (y - y.min()) / max(y.max() - y.min(), 1e-9)
    xtr, ytr, xte, yte = _split(x, y)
    return HeterogeneousDataset(
        xtr.astype(np.float32), ytr.astype(np.float32),
        xte.astype(np.float32), yte.astype(np.float32), name,
        cluster=cluster.astype(np.int32), num_tasks=num_tasks)


# ---------------------------------------------------------------------------
# Streaming: per-agent minibatch streams (the online-learning workload)
# ---------------------------------------------------------------------------

#: stream generator kinds `stream_synthetic` implements (and
#: `FitConfig.stream` validates against)
STREAM_KINDS = ("stationary", "drift", "shift")


@dataclasses.dataclass(frozen=True)
class StreamDataset:
    """Per-agent minibatch stream: round k hands agent n the fresh
    minibatch (x[k, n], y[k, n]) — the online-learning protocol's
    data arrival order is materialized up front so the whole stream
    is jit-traceable (sliced per round inside the scan)."""

    x: np.ndarray  # (R, N, b, d) in [0, 1]
    y: np.ndarray  # (R, N, b)
    kind: str
    name: str = "stream"

    @property
    def num_rounds(self) -> int:
        return self.x.shape[0]

    @property
    def num_agents(self) -> int:
        return self.x.shape[1]

    @property
    def batch(self) -> int:
        return self.x.shape[2]

    @property
    def input_dim(self) -> int:
        return self.x.shape[-1]


def stream_synthetic(
    kind: str = "stationary",
    num_rounds: int = 200,
    num_agents: int = 6,
    batch: int = 16,
    input_dim: int = 5,
    num_components: int = 50,
    bandwidth: float = 5.0,
    noise_std: float = np.sqrt(0.1),
    drift: float = 1.0,
    shift: float = 2.0,
    seed: int = 0,
) -> StreamDataset:
    """The paper's synthetic model extended to a stream.

    kind — "stationary": the Section-5.1 mixture, fresh draws per round;
           "drift" (concept drift): the mixture *weights* interpolate
           b(k) = (1-t_k) b0 + t_k b1 between two independent draws
           (t_k = drift * k/(R-1), clipped to [0, 1]) — the target
           function itself moves while the inputs stay iid;
           "shift" (covariate shift): the input mean slides
           m_k = shift * t_k * u along a fixed random direction u while
           the target function stays fixed — the regressor sees a moving
           slice of an unchanged surface.
    """
    if kind not in STREAM_KINDS:
        raise ValueError(
            f"unknown stream kind {kind!r}; choose from {STREAM_KINDS}")
    rng = np.random.default_rng(seed)
    b0 = rng.uniform(0.0, 1.0, num_components)
    b1 = rng.uniform(0.0, 1.0, num_components)
    c = rng.normal(size=(num_components, input_dim))
    u = rng.normal(size=input_dim)
    u /= np.linalg.norm(u)

    t = (np.arange(num_rounds) / max(num_rounds - 1, 1)).astype(np.float64)
    x = rng.normal(size=(num_rounds, num_agents, batch, input_dim))
    if kind == "shift":
        x = x + (shift * t)[:, None, None, None] * u
    if kind == "drift":
        w = np.clip(drift * t, 0.0, 1.0)
        b_k = (1.0 - w)[:, None] * b0 + w[:, None] * b1   # (R, M)
    else:
        b_k = np.broadcast_to(b0, (num_rounds, num_components))

    # y[k] = sum_m b_m(k) exp(-||c_m - x||^2 / (2 sigma^2)) + e, one round
    # at a time — the (N, b, M, d) intermediate stays round-sized.
    y = np.empty((num_rounds, num_agents, batch))
    for k in range(num_rounds):
        sq = ((x[k][:, :, None, :] - c[None, None, :, :]) ** 2).sum(-1)
        y[k] = np.exp(-sq / (2.0 * bandwidth**2)) @ b_k[k]
    y += rng.normal(scale=noise_std, size=y.shape)

    # global normalization (matching paper_synthetic's protocol): inputs to
    # [0, 1] per coordinate, labels to [0, 1] — so censor thresholds bite
    # the same way they do on the batch problem
    lo = x.min(axis=(0, 1, 2), keepdims=True)
    hi = x.max(axis=(0, 1, 2), keepdims=True)
    x = (x - lo) / np.maximum(hi - lo, 1e-9)
    y = (y - y.min()) / max(y.max() - y.min(), 1e-9)
    return StreamDataset(x.astype(np.float32), y.astype(np.float32),
                         kind=kind, name=f"stream-{kind}")


# Published (samples, input_dim) of the Section-5.2 UCI datasets.
UCI_SPECS = {
    "toms_hardware": (11000, 96),
    "twitter": (13800, 77),
    "twitter_large": (98704, 77),
    "energy": (19735, 28),
    "air_quality": (9358, 13),
}


def uci_standin(
    name: str,
    num_agents: int = 10,
    seed: int = 1,
    subsample: int | None = 4000,
) -> Dataset:
    """Offline stand-in with the published dims of the named UCI dataset."""
    total, dim = UCI_SPECS[name]
    if subsample is not None:
        total = min(total, subsample)
    per_agent = total // num_agents
    # zlib.crc32, not hash(): str hashing is salted per process, which made
    # every stand-in dataset (and all UCI benchmark numbers) differ run-to-run
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2**16)

    # Smooth nonlinear surface: random low-rank features + sinusoidal response.
    proj = rng.normal(size=(dim, 8)) / np.sqrt(dim)
    w = rng.normal(size=8)
    x = rng.uniform(size=(num_agents, per_agent, dim))
    z = np.tanh(x @ proj)
    y = np.sin(z @ w) + 0.1 * (z**2 @ np.abs(w)) \
        + rng.normal(scale=0.05, size=(num_agents, per_agent))

    x = _normalize01(x)
    y = (y - y.min()) / max(y.max() - y.min(), 1e-9)
    xtr, ytr, xte, yte = _split(x, y)
    return Dataset(xtr.astype(np.float32), ytr.astype(np.float32),
                   xte.astype(np.float32), yte.astype(np.float32), name)
