"""Data substrate: the paper's datasets + the framework's LM token pipeline."""
from repro.data import synthetic, tokens  # noqa: F401
from repro.data.synthetic import Dataset, paper_synthetic, uci_standin  # noqa: F401
from repro.data.tokens import TokenStream, TokenStreamConfig  # noqa: F401
