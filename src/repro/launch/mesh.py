"""Production meshes.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips; the "pod"
    axis composes with "data" for batch / consensus-agent sharding."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the host's actual devices (tests / smoke runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that jointly shard the batch / consensus-agent dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def num_agents(mesh) -> int:
    """Number of consensus agents = product of batch axes."""
    import math
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))
