"""Trip-count-aware HLO analyzer.

XLA's `compiled.cost_analysis()` (and any naive text scan) counts a while
loop's body ONCE, but `lax.scan` over 126 layers executes it 126 times — so
FLOPs, HBM bytes, and collective bytes would all be undercounted by the
layer count. This analyzer parses the post-optimization HLO text into a call
graph, reads loop trip counts from `backend_config known_trip_count` (with a
condition-compare-constant fallback), and propagates execution multipliers
from ENTRY through while / fusion / call / conditional edges. Per device it
reports:

  * dot_flops        — 2 * prod(output dims) * prod(contracting dims) per
                       dot, multiplier-weighted (matmul FLOPs, the MFU
                       convention),
  * hbm_bytes        — operand + output bytes of control-level instructions
                       (fusion internals excluded: they live in registers /
                       VMEM), multiplier-weighted — a proxy for HBM traffic,
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       multiplier-weighted, split by kind.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z]\d*[a-z]*\d*\[[\d,]*\]\S*)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_PARAM_DECL_RE = re.compile(r"([\w\.\-]+):\s*([a-z]\d*[a-z]*\d*\[[\d,]*\])")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 0)


def _type_bytes(type_str: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _type_elems(type_str: str) -> int:
    return sum(_shape_elems(dims) for _, dims in _SHAPE_RE.findall(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


def parse_computations(hlo: str):
    """-> (computations by name, name->out_type symbol table)."""
    comps: dict[str, Computation] = {}
    symbols: dict[str, str] = {}
    current = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and stripped.endswith("{"):
            is_entry = stripped.startswith("ENTRY")
            header = stripped[len("ENTRY"):].strip() if is_entry else stripped
            m = re.match(r"%?([\w\.\-]+)\s*\(", header)
            if m:
                current = Computation(m.group(1), [], is_entry)
                comps[current.name] = current
                # parameter declarations carry shapes
                for pname, ptype in _PARAM_DECL_RE.findall(header):
                    symbols[pname] = ptype
            continue
        if stripped == "}" or stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), stripped)
            current.instrs.append(ins)
            symbols[ins.name] = ins.out_type
    return comps, symbols


def _operand_names(line: str, opcode: str | None = None) -> list[str]:
    """Operand names of the CALL parens — for tuple-typed instructions
    (variadic all-reduce etc.) the first '(' after '=' is the tuple type,
    so locate the parens following the opcode itself."""
    if opcode is not None:
        pos = line.find(f" {opcode}(")
        paren = line.find("(", pos + 1) if pos >= 0 else -1
    else:
        paren = line.find("(", line.find("=") + 1)
    if paren < 0:
        return []
    depth = 0
    end = paren
    for i in range(paren, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    section = line[paren + 1:end]
    return re.findall(r"%([\w\.\-]+)", section)


def _operand_bytes(line: str, symbols: dict, opcode: str | None = None) -> int:
    return sum(_type_bytes(symbols.get(n, ""))
               for n in _operand_names(line, opcode))


def _dot_flops(ins: Instr, symbols: dict) -> float:
    out_elems = _type_elems(ins.out_type)
    ops = _operand_names(ins.line, ins.opcode)
    if not ops:
        return 0.0
    lhs_type = symbols.get(ops[0], "")
    shapes = _SHAPE_RE.findall(lhs_type)
    if not shapes:
        return 0.0
    lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _trip_count(ins: Instr, comps: dict) -> int:
    m = _TRIP_RE.search(ins.line)
    if m:
        return int(m.group(1))
    # fallback: largest compare constant in the condition computation
    mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
    best = 1
    if mc and mc.group(1) in comps:
        for cins in comps[mc.group(1)].instrs:
            if cins.opcode in ("compare", "constant"):
                for c in re.findall(r"constant\((\d+)\)", cins.line):
                    best = max(best, int(c))
    return best


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "call", "conditional",
                   "after-all", "iota"}


def analyze_hlo(hlo: str) -> dict:
    comps, symbols = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"dot_flops": 0.0, "hbm_bytes": 0.0,
                "collective_bytes": {k: 0.0 for k in COLLECTIVES},
                "trip_counts": {}}

    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    fusion_bodies: set[str] = set()
    order = [entry.name]
    queued = {entry.name}
    trip_counts: dict[str, int] = {}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]

        def enqueue(callee, factor, fusion=False):
            mult[callee] += m * factor
            if fusion:
                fusion_bodies.add(callee)
            if callee not in queued:
                queued.add(callee)
                order.append(callee)

        for ins in comp.instrs:
            if ins.opcode == "while":
                trips = _trip_count(ins, comps)
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if mb:
                    trip_counts[mb.group(1)] = trips
                    enqueue(mb.group(1), trips)
                if mc:
                    enqueue(mc.group(1), trips + 1)
            elif ins.opcode == "fusion":
                mcal = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if mcal:
                    enqueue(mcal.group(1), 1, fusion=True)
            elif ins.opcode == "call":
                mcal = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
                if mcal:
                    enqueue(mcal.group(1), 1)
            elif ins.opcode == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if mbr:
                    for b in mbr.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b:
                            enqueue(b, 1)

    dot_flops = 0.0
    hbm_bytes = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    for cname in order:
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        if m == 0:
            continue
        in_fusion = cname in fusion_bodies
        for ins in comp.instrs:
            if ins.opcode == "dot":
                dot_flops += m * _dot_flops(ins, symbols)
            kind = next((k for k in COLLECTIVES
                         if ins.opcode == k or ins.opcode == k + "-start"),
                        None)
            if kind:
                coll[kind] += m * _operand_bytes(ins.line, symbols,
                                                 ins.opcode)
            if not in_fusion and ins.opcode not in _SKIP_BYTES_OPS:
                hbm_bytes += m * (_type_bytes(ins.out_type)
                                  + _operand_bytes(ins.line, symbols,
                                                   ins.opcode))

    return {"dot_flops": dot_flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": coll, "trip_counts": trip_counts}
