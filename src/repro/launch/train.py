"""Training driver.

Host-scale runs execute on the local device(s); the production meshes are
exercised via dryrun.py. Supports every consensus strategy:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --strategy coke --agents 4 --steps 50
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.ckpt import save
from repro.configs import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.distributed.consensus import ConsensusConfig
from repro.optim.optimizers import OptConfig
from repro.train.steps import agent_batch, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced family variant (CPU-runnable)")
    ap.add_argument("--strategy", default="allreduce",
                    choices=["allreduce", "dkla", "coke", "coke_et", "cta"])
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rho", type=float, default=1e-3)
    ap.add_argument("--censor-v", type=float, default=1.0)
    ap.add_argument("--censor-mu", type=float, default=0.99)
    ap.add_argument("--local-steps", type=int, default=1,
                    help="coke_et: local steps per consensus round")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = OptConfig(kind="adamw", lr=args.lr, grad_clip=1.0)
    ccfg = None
    if args.strategy != "allreduce":
        ccfg = ConsensusConfig(strategy=args.strategy, rho=args.rho,
                               censor_v=args.censor_v,
                               censor_mu=args.censor_mu,
                               local_steps=args.local_steps)
    init_fn, step_fn, local_fn = make_train_step(
        cfg, opt_cfg, ccfg, num_agents=args.agents)
    state = init_fn(jax.random.PRNGKey(0))
    step_j = jax.jit(step_fn)
    local_j = jax.jit(local_fn) if local_fn is not None else None

    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    t0 = time.time()
    for i in range(args.steps):
        toks, labels = stream.batch(i)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if ccfg is not None:
            batch = agent_batch(batch, args.agents)
            if (args.strategy == "coke_et"
                    and (i + 1) % max(args.local_steps, 1) != 0):
                state, metrics = local_j(state, batch)
            else:
                state, metrics = step_j(state, batch)
        else:
            state, metrics = step_j(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            print(json.dumps({"step": i, **m,
                              "wall_s": round(time.time() - t0, 1)}),
                  flush=True)

    if args.ckpt:
        save(args.ckpt, state["params"] if "params" in state else state,
             step=args.steps)
        print(f"saved checkpoint to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
