import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production meshes with ShapeDtypeStruct stand-ins —
no allocation, no execution. Proves the distribution config is coherent and
extracts memory / cost / collective data for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID|all]
      [--shape NAME|all] [--mesh single|multi|both]
      [--strategy allreduce|coke|coke_et] [--fsdp] [--tag NAME] [--force]

Results cached to results/dryrun/<tag>.json per combination (re-runs skip
completed entries unless --force).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, input_specs, list_archs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.distributed.consensus import ConsensusConfig  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import batch_axes, make_production_mesh, num_agents  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim.optimizers import OptConfig  # noqa: E402
from repro.train.steps import make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def _tree_specs_for_state(cfg, state_shapes, mesh, fsdp):
    """Shardings for a train state: params rules apply throughout (opt m/v
    mirror param paths), scalars replicate."""
    return shd.param_specs(cfg, state_shapes, mesh, fsdp=fsdp)


def _agent_stack_specs(cfg, state_shapes, mesh, fsdp):
    """Consensus state: leading agent axis over the batch axes on every
    stacked leaf; inner dims follow the param rules computed on the
    agent-STRIPPED shapes (the rules are positional in the stack depth)."""
    ba = batch_axes(mesh)
    N = num_agents(mesh)

    def strip(leaf):
        if leaf.ndim >= 1 and leaf.shape and leaf.shape[0] == N:
            return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
        return leaf

    stripped = jax.tree.map(strip, state_shapes)
    base = shd.param_specs(cfg, stripped, mesh, fsdp=False)

    def add_agent(spec, leaf):
        if leaf.ndim >= 1 and leaf.shape and leaf.shape[0] == N:
            inner = list(spec)[: leaf.ndim - 1]
            inner += [None] * (leaf.ndim - 1 - len(inner))
            return P(ba, *inner)
        return P(*list(spec)[: leaf.ndim])

    return jax.tree.map(add_agent, base, state_shapes)


def lower_one(arch: str, shape_name: str, mesh, *, strategy="allreduce",
              fsdp=False, seq_parallel=False, microbatches=1, head_pad=0,
              donate=True):
    """Returns (lowered, compiled, meta)."""
    cfg = get_config(arch).with_overrides(dtype=jnp.bfloat16)
    if head_pad:
        cfg = cfg.with_overrides(tp_head_pad=head_pad)
    if seq_parallel:
        ba = batch_axes(mesh)
        cfg = cfg.with_overrides(seq_parallel=True, act_batch_axes=ba)
        jax.set_mesh(mesh)
    rcfg, kind, specs = input_specs(cfg, shape_name)
    if rcfg is None:
        return None, None, {"skipped": True,
                            "reason": "long_500k inapplicable (DESIGN.md)"}
    shape = SHAPES[shape_name]

    def ns(spec_tree):
        """PartitionSpec pytree -> NamedSharding pytree on this mesh."""
        return jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    with mesh:
        if kind == "train":
            opt_cfg = OptConfig(kind="adamw", lr=1e-4)
            if strategy == "allreduce":
                init_fn, step_fn, _ = make_train_step(
                    rcfg, opt_cfg, microbatches=microbatches)
                state_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
                state_specs = _tree_specs_for_state(rcfg, state_shapes, mesh,
                                                    fsdp)
                batch_sp = shd.batch_specs(rcfg, specs, mesh)
                fn = jax.jit(step_fn,
                             in_shardings=(ns(state_specs), ns(batch_sp)),
                             out_shardings=(ns(state_specs), None),
                             donate_argnums=(0,) if donate else ())
                lowered = fn.lower(state_shapes, specs)
            else:
                N = num_agents(mesh)
                ccfg = ConsensusConfig(strategy=strategy, rho=1e-3,
                                       track_gap=False)
                init_fn, step_fn, local_fn = make_train_step(
                    rcfg, opt_cfg, ccfg, num_agents=N)
                if strategy == "coke_et_local":
                    step_fn = local_fn
                state_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
                state_specs = _agent_stack_specs(rcfg, state_shapes, mesh,
                                                 fsdp)
                # batch gains agent axis: (N, B/N, ...)
                def stack_spec(leaf):
                    B = leaf.shape[0]
                    n = (N, B // N, *leaf.shape[1:])
                    return jax.ShapeDtypeStruct(n, leaf.dtype)
                specs_stacked = jax.tree.map(stack_spec, specs)
                ba = batch_axes(mesh)
                batch_sp = jax.tree.map(
                    lambda leaf: P(ba, *([None] * (leaf.ndim - 1))),
                    specs_stacked)
                fn = jax.jit(step_fn,
                             in_shardings=(ns(state_specs), ns(batch_sp)),
                             out_shardings=(ns(state_specs), None),
                             donate_argnums=(0,) if donate else ())
                lowered = fn.lower(state_shapes, specs_stacked)
        elif kind == "prefill":
            param_shapes = model_lib.param_shapes(rcfg)
            p_specs = shd.param_specs(rcfg, param_shapes, mesh, fsdp=fsdp)
            batch_sp = shd.batch_specs(rcfg, specs, mesh)
            fn = jax.jit(lambda p, b: model_lib.prefill(p, rcfg, b),
                         in_shardings=(ns(p_specs), ns(batch_sp)))
            lowered = fn.lower(param_shapes, specs)
        else:  # decode
            param_shapes = model_lib.param_shapes(rcfg)
            p_specs = shd.param_specs(rcfg, param_shapes, mesh, fsdp=fsdp)
            in_sp = shd.step_in_specs(rcfg, kind, specs, mesh)
            fn = jax.jit(
                lambda p, t, s, pos: model_lib.decode_step(p, rcfg, t, s,
                                                           pos),
                in_shardings=(ns(p_specs), ns(in_sp["token"]),
                              ns(in_sp["state"]), ns(in_sp["position"])),
                out_shardings=(None, ns(in_sp["state"])),
                donate_argnums=(2,) if donate else ())
            lowered = fn.lower(param_shapes, specs["token"], specs["state"],
                               specs["position"])

        compiled = lowered.compile()

    n_dev = mesh.size
    p_shapes = (state_shapes["params"] if kind == "train" and
                strategy == "allreduce" else
                state_shapes["params"] if kind == "train" else param_shapes)
    n_params = analysis.count_params(
        jax.tree.map(lambda x: x, p_shapes))
    n_active = analysis.active_params(rcfg, p_shapes)
    if kind == "train" and strategy != "allreduce":
        # stacked agent axis inflates the count; normalize
        n_params //= num_agents(mesh)
        n_active //= num_agents(mesh)
    meta = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names), "devices": n_dev,
        "strategy": strategy, "fsdp": fsdp,
        "params": int(n_params), "active_params": int(n_active),
        "global_batch": shape.global_batch, "seq_len": shape.seq_len,
    }
    return lowered, compiled, meta


def analyze(lowered, compiled, meta) -> dict:
    from repro.launch.hlo_analyzer import analyze_hlo
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    hres = analyze_hlo(hlo)  # trip-count-aware FLOPs/bytes/collectives
    roof = analysis.roofline(
        {"flops": hres["dot_flops"], "bytes accessed": hres["hbm_bytes"]},
        hres["collective_bytes"])
    roof["xla_cost_flops_body_once"] = float(cost.get("flops", 0.0))
    roof["xla_cost_bytes_body_once"] = float(cost.get("bytes accessed", 0.0))
    mf = analysis.model_flops(
        get_config(meta["arch"]), meta["kind"], meta["global_batch"],
        meta["seq_len"], meta["active_params"])
    roof["model_flops"] = mf
    roof["useful_fraction"] = analysis.efficiency(
        roof["flops_per_device"], meta["devices"], mf)
    result = dict(meta)
    result["roofline"] = roof
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    return result


def run_pair(arch, shape_name, mesh_kind, *, strategy="allreduce",
             fsdp=False, seq_parallel=False, microbatches=1, head_pad=0,
             tag=None, force=False) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = tag or f"{arch}_{shape_name}_{mesh_kind}_{strategy}" + \
        ("_fsdp" if fsdp else "") + ("_seqpar" if seq_parallel else "") + \
        (f"_mb{microbatches}" if microbatches > 1 else "") + \
        (f"_hp{head_pad}" if head_pad else "")
    path = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_one(arch, shape_name, mesh,
                                            strategy=strategy, fsdp=fsdp,
                                            seq_parallel=seq_parallel,
                                            microbatches=microbatches,
                                            head_pad=head_pad)
        if compiled is None:
            result = dict(meta, arch=arch, shape=shape_name,
                          mesh_kind=mesh_kind)
        else:
            result = analyze(lowered, compiled, meta)
            result["mesh_kind"] = mesh_kind
        result["status"] = "skipped" if compiled is None else "ok"
    except Exception as e:  # record failures — they are bugs to fix
        result = {"arch": arch, "shape": shape_name, "mesh_kind": mesh_kind,
                  "strategy": strategy, "fsdp": fsdp, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    result["elapsed_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default="allreduce")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--seqpar", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--headpad", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                r = run_pair(arch, shape_name, mesh_kind,
                             strategy=args.strategy, fsdp=args.fsdp,
                             seq_parallel=args.seqpar,
                             microbatches=args.microbatch,
                             head_pad=args.headpad,
                             tag=args.tag, force=args.force)
                status = r.get("status")
                line = f"{arch:24s} {shape_name:12s} {mesh_kind:6s} {status}"
                if status == "ok":
                    roof = r["roofline"]
                    line += (f" dom={roof['dominant']:10s}"
                             f" c={roof['compute_s']:.3e}"
                             f" m={roof['memory_s']:.3e}"
                             f" n={roof['collective_s']:.3e}"
                             f" useful={roof['useful_fraction']:.2f}"
                             f" ({r['elapsed_s']}s)")
                elif status == "error":
                    n_fail += 1
                    line += " " + r["error"][:120]
                print(line, flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run failures")


if __name__ == "__main__":
    main()
