"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds per step, per chip:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

HLO_FLOPs / bytes come from compiled.cost_analysis() of the SPMD-partitioned
(= per-device) program. collective bytes are NOT in cost_analysis: we parse
the compiled HLO text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e-class target given by the assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `bf16[2,128,1024]{2,1,0}` (layout suffix optional); scalars: `f32[]`
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes (per-device program)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+)", stripped)
        if not m:
            continue
        op = m.group(1)
        kind = next((k for k in _COLLECTIVES if op == k or
                     op.startswith(k + "-start")), None)
        if kind is None:
            continue
        # operand shapes: everything inside the top-level call parens
        paren = stripped.find("(", m.end())
        if paren < 0:
            continue
        args = stripped[paren:]
        # stop at metadata to avoid counting shapes in attributes
        for stop in ("replica_groups", "source_target_pairs", "metadata",
                     "channel_id", "dimensions"):
            idx = args.find(stop)
            if idx > 0:
                args = args[:idx]
                break
        for dt, dims in _SHAPE_RE.findall(args):
            out[kind] += _shape_bytes(dt, dims)
    return out


def roofline(cost: dict, coll_bytes: dict[str, int]) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(coll_bytes.values()))
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_accessed / HBM_BW
    coll_t = cbytes / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_t}
    dominant = max(terms, key=terms.get)
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": cbytes,
        "collective_breakdown": coll_bytes,
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "step_s_lower_bound": max(terms.values()),
    }


def count_params(shapes_pytree) -> int:
    import jax
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes_pytree))


def active_params(cfg, shapes_pytree) -> int:
    """Active (per-token) params: MoE counts top_k + shared experts only."""
    import jax
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes_pytree)[0]
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = math.prod(leaf.shape)
        if cfg.is_moe and any(k in ("w_gate", "w_up", "w_down")
                              for k in keys) and leaf.ndim >= 3 \
                and leaf.shape[-3] == cfg.num_experts:
            n = n * cfg.top_k // cfg.num_experts
        total += n
    return total


def model_flops(cfg, kind: str, global_batch: int, seq_len: int,
                n_active: int) -> float:
    """6*N*D (train) or 2*N*D (forward-only), D = tokens per step.

    Enc-dec: a token traverses only its branch (~half the params), so the
    effective N*D halves (enc tokens never see the decoder and vice versa).
    """
    branch = 0.5 if getattr(cfg, "encoder_layers", 0) else 1.0
    if kind == "train":
        return 6.0 * n_active * branch * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_active * branch * global_batch * seq_len
    return 2.0 * n_active * branch * global_batch  # decode: one new token


def efficiency(cost_flops_per_device: float, num_devices: int,
               mflops: float) -> float:
    """MODEL_FLOPS / HLO_FLOPS (global) — >1 impossible; <<1 = waste
    (remat recompute, attention quadratic term, dispatch overhead)."""
    hlo_global = cost_flops_per_device * num_devices
    return mflops / hlo_global if hlo_global else 0.0
