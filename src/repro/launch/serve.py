"""Serving driver: batched greedy generation with the KV/SSM-state caches.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
      --batch 4 --prompt-len 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    extra = {}
    if cfg.is_encdec:
        import jax.numpy as jnp
        extra["encoder_embeds"] = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(args.batch, 16, cfg.d_model)).astype(np.float32))
    eng = Engine(cfg, params,
                 ServeConfig(max_new_tokens=args.new_tokens,
                             cache_len=args.cache_len), extra_batch=extra)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} new={args.new_tokens} "
          f"wall={dt:.2f}s tok/s={args.batch * args.new_tokens / dt:.1f}")
    print("generated ids:\n", out)


if __name__ == "__main__":
    main()
