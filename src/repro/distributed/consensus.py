"""The paper's technique as a first-class data-parallel strategy.

Each data-parallel shard (the `pod` x `data` mesh axes jointly) is one COKE
*agent* holding its own parameter copy theta_i; the consensus graph is the
ring matching the ICI torus. All agent-axis operations are expressed as
plain jnp over a leading stacked agent dimension sharded over the batch
axes — `jnp.roll` along that dimension lowers to `collective-permute`, so
the neighbor exchange costs two permutes per step instead of an all-reduce.

Strategies:
  allreduce — standard DP (mean gradient; the framework baseline),
  dkla      — decentralized ADMM (Alg. 1) with an inexact inner argmin
              (one optimizer step on the augmented Lagrangian),
  coke      — dkla + communication censoring (Alg. 2); in SPMD the permute
              always executes but carries the *stale* theta_hat when
              censored — semantically identical to not transmitting; the
              paper's metric (# transmissions) is counted exactly,
  cta       — diffusion combine-then-adapt baseline (ring Metropolis mix),
  coke_et   — beyond-paper event-triggered variant: `local_steps` purely
              local optimizer steps between consensus rounds, which REMOVES
              the collectives from the lowered graph for censored steps
              (a real bytes saving visible in the roofline).

The broadcast itself is governed by a `repro.core.comm` policy chain
(censor / quantize / drop with bit-level accounting) passed to
`consensus_update(comm=...)`; the legacy `censor_v`/`censor_mu` knobs map
onto the equivalent censor-only chain. Time-varying circulant topologies
(`offset_schedule`) cycle the permute pattern per iteration via lax.switch.

Big-D layout: every agent-axis operation here is plain jnp over stacked
trees, so the whole update is feature-shardable — place the carry with
`distributed.sharding.shard_features` (theta/theta_hat/gamma as
(N, D/shards) per device over the mesh's "model" axis; `repro.api.fit(
mesh=...)` does this) and GSPMD keeps the layout through the scan: the
rolls stay collective-permutes over the batch axes, elementwise updates
stay local, and the censor norm's sum over the sharded feature dim
(`_agent_norms` / `core.comm`'s censor_decision) lowers to one psum.
The exact big-D primal plugs in via `consensus_update(primal_solve=...)`
— the matrix-free CG solve of (21a) — replacing the one-step inexact
update (see repro.api.backends._cg_primal_solve).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.optim.optimizers import (OptConfig, apply_updates,
                                    init_opt_state, opt_update)


@partial(jax.tree_util.register_dataclass, data_fields=(),
         meta_fields=("strategy", "rho", "censor_v", "censor_mu",
                      "local_steps", "mix_weight", "track_gap", "offsets",
                      "offset_schedule", "use_fused_kernel"))
@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    strategy: str = "allreduce"  # allreduce | dkla | coke | cta | coke_et
    rho: float = 1e-3
    censor_v: float = 1.0
    censor_mu: float = 0.99
    local_steps: int = 1         # coke_et: local steps per consensus round
    mix_weight: float = 1.0 / 3.0  # cta ring mixing (self + 2 neighbors)
    # consensus_gap is an all-reduce of the full parameter tree — keep it
    # out of the hot step unless explicitly requested (§Perf pair C).
    track_gap: bool = True
    # circulant topology: agent i ~ i±o for each offset o. (1,) = ring;
    # (1, k) = 2k-regular circulant — denser graphs raise sigma_min(S_-)
    # (faster consensus per Thm 2) at 2 extra permutes per added offset.
    offsets: tuple = (1,)
    # time-varying topology: a tuple of offset tuples, cycled per iteration
    # (graph (k-1) % M at step k — core.graph.TopologySchedule semantics).
    # Each variant lowers to its own lax.switch branch of permutes. The
    # neighbor cache is bypassed (the cached fetch belongs to the previous
    # step's graph) and the fused kernel is unsupported (static degree).
    offset_schedule: tuple | None = None
    # route the augmented-gradient + censor-norm computation through the
    # fused Pallas kernel (repro.kernels.coke_update) — compiled on
    # TPU/GPU, interpret mode on CPU (tests assert equality). The full
    # megakernel path (one pallas_call per iteration) lives one level up,
    # in api.backends' StepProgram runner; this flag covers the configs
    # the megakernel doesn't admit (cg primal, coke_et, schedules).
    use_fused_kernel: bool = False

    @property
    def degree(self) -> float:
        return 2.0 * len(self.offsets)

    @property
    def is_admm(self) -> bool:
        return self.strategy in ("dkla", "coke", "coke_et")

    def comm_chain(self) -> comm_mod.Chain:
        """The legacy (censor_v, censor_mu) knobs as a core.comm policy —
        what consensus_update runs when no explicit chain is passed."""
        if self.strategy == "dkla":
            return comm_mod.Chain(())
        return comm_mod.Chain((comm_mod.Censor(self.censor_v,
                                               self.censor_mu),))


def needs_agent_stack(cfg: ConsensusConfig) -> bool:
    return cfg.strategy != "allreduce"


# ---------------------------------------------------------------------------
# Agent-stacked state
# ---------------------------------------------------------------------------

def stack_params(params, num_agents: int):
    """Broadcast params to a leading agent axis (all agents start equal,
    matching theta^0 identical across agents)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_agents, *p.shape)), params)


def init_consensus_state(ccfg: ConsensusConfig, opt_cfg: OptConfig,
                         params_stacked, comm=None) -> dict[str, Any]:
    """State carried across steps alongside the stacked params.

    comm — the communication policy chain whose persistent state (per-agent
    cumulative bits, stage states) rides in the consensus state; None =
    the legacy chain derived from ccfg (censor for coke, broadcast for
    dkla). Must structurally match the chain later passed to
    consensus_update."""
    state: dict[str, Any] = {
        "opt": jax.vmap(lambda p: init_opt_state(opt_cfg, p))(params_stacked),
        "step": jnp.zeros((), jnp.int32),
        "comms": jnp.zeros((), jnp.int32),
    }
    if ccfg.is_admm:
        chain = ccfg.comm_chain() if comm is None else comm_mod.as_chain(comm)
        num_agents = jax.tree.leaves(params_stacked)[0].shape[0]
        state["comm"] = chain.init_state(num_agents)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_stacked)
        theta_hat = jax.tree.map(
            lambda p: p.astype(jnp.float32), params_stacked)
        state["gamma"] = zeros
        state["theta_hat"] = theta_hat
        # cached neighbor broadcasts: all agents start identical, so the
        # initial cache equals theta_hat itself (exact). Caching the dual-
        # update fetch for the next primal step halves the permute bytes
        # (4 -> 2 per iteration) with bit-identical iterates (§Perf).
        state["nbr_left"] = theta_hat
        state["nbr_right"] = theta_hat
    return state


# ---------------------------------------------------------------------------
# Ring primitives over the agent axis
# ---------------------------------------------------------------------------

def _ring_neighbors(tree, offsets: tuple = (1,)):
    """Circulant neighbor copies via roll on the agent axis (each roll
    lowers to a collective-permute when that axis is mesh-sharded).
    Returns (sum_of_neighbors_left..., right...) halves as a pair of
    summed trees so callers stay offset-agnostic."""
    left = None
    right = None
    for o in offsets:
        l_o = jax.tree.map(lambda x: jnp.roll(x, o, axis=0), tree)
        r_o = jax.tree.map(lambda x: jnp.roll(x, -o, axis=0), tree)
        left = l_o if left is None else jax.tree.map(jnp.add, left, l_o)
        right = r_o if right is None else jax.tree.map(jnp.add, right, r_o)
    return left, right


def _scheduled_neighbors(tree, variants: tuple, idx):
    """Neighbor fetch under a time-varying circulant schedule: one
    lax.switch branch of permutes per offset variant, selected by the
    (traced) graph index `idx`."""
    branches = [partial(_ring_neighbors, offsets=off) for off in variants]
    return jax.lax.switch(idx, branches, tree)


def _agent_norms(diff_tree) -> jax.Array:
    """Per-agent l2 norm over all parameters: (N,)."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)),
                     axis=tuple(range(1, x.ndim)))
             for x in jax.tree.leaves(diff_tree))
    return jnp.sqrt(sq)


def _mask_rows(m: jax.Array, new, old):
    """Row-select over agent-stacked pytrees: agent i's leaves take `new`
    iff m[i] (gossip participation); scalar leaves pass through. With an
    all-true mask this is bitwise `new` — the degenerate-gossip contract."""
    def sel(a, b):
        if a.ndim == 0:
            return a
        return jnp.where(m.reshape(m.shape + (1,) * (a.ndim - 1)), a, b)
    return jax.tree.map(sel, new, old)


# ---------------------------------------------------------------------------
# One consensus update given per-agent local gradients
# ---------------------------------------------------------------------------

def _degb(deg: jax.Array, x: jax.Array) -> jax.Array:
    """Broadcast a (N,) weighted-degree vector against an agent-stacked
    leaf (N, ...) — the dense-graph analogue of the scalar circulant
    degree."""
    return deg.reshape((deg.shape[0],) + (1,) * (x.ndim - 1))


def _dense_neighbors(adjacency: jax.Array, tree):
    """sum_n w_in x_n per agent: one (N, N) x (N, ...) contraction per
    leaf — the dense-graph analogue of the circulant permute halves.
    Matches the simulator's `A @ theta_hat` contraction bit-for-bit on
    (N, D) leaves."""
    return jax.tree.map(
        lambda x: jnp.tensordot(adjacency, x.astype(jnp.float32), axes=1),
        tree)


def _alive_ring_sum(tree, alive_f: jax.Array, offsets: tuple):
    """Liveness-masked circulant neighbor sum: dead agents' values are
    zeroed before the permutes, so each surviving agent accumulates
    exactly `sum_n alive_n x_n` — bit-identical to the simulator's
    alive-weighted NeighborTable gather on deg-2 rings (masking by
    1.0/0.0 is exact; the two-term partial sums commute)."""
    masked = jax.tree.map(lambda x: x * _degb(alive_f, x), tree)
    left, right = _ring_neighbors(masked, offsets)
    return jax.tree.map(jnp.add, left, right)


# ---------------------------------------------------------------------------
# Exchange stages (core.step's `exchange` slot, spmd flavors)
# ---------------------------------------------------------------------------
#
# One iteration's view of the graph, bundling the (possibly per-agent)
# degrees, the primal neighbor sum, and the expression family for the
# augmented gradient and the (21b) dual. Two families exist because their
# float associativity differs and each is pinned by parity tests:
#
#   halves — static/scheduled circulants: `deg*th + l + r` three-term adds
#            over the permute halves, with the dual fetch refilling the
#            neighbor cache (2 permutes per iteration);
#   summed — dense (learned) graphs and churn-masked rings: `deg` is a
#            per-agent (N,) vector and the neighbor view is a single
#            summed tree, matching the simulator's expressions
#            bit-for-bit; the circulant cache is stale under a
#            per-iteration graph, so it is carried untouched.

@dataclasses.dataclass(frozen=True)
class _Exchange:
    deg: Any            # scalar / 0-d (circulant) or (N,) vector degrees
    nbr_sum: Any        # summed neighbor tree of theta_hat^{k-1}
    g_aug: Any          # (grads, params, theta_hat, gamma) -> tree
    dual: Any           # (gamma, new_theta_hat) -> (gamma', cache_l, cache_r)


def _halves_exchange(rho, deg, left, right, dual_fetch) -> _Exchange:
    def g_aug(grads, params, theta_hat, gamma):
        return jax.tree.map(
            lambda g, p, th, gm, l, r: (
                g.astype(jnp.float32)
                + 2.0 * rho * deg * p.astype(jnp.float32)
                + gm
                - rho * (deg * th + l + r)),
            grads, params, theta_hat, gamma, left, right)

    def dual(gamma, new_theta_hat):
        hat_l, hat_r = dual_fetch(new_theta_hat)
        new_gamma = jax.tree.map(
            lambda gm, th, l, r: gm + rho * (deg * th - l - r),
            gamma, new_theta_hat, hat_l, hat_r)
        return new_gamma, hat_l, hat_r

    return _Exchange(deg, jax.tree.map(jnp.add, left, right), g_aug, dual)


def _summed_exchange(rho, deg, nbr_sum, dual_fetch, cache) -> _Exchange:
    def g_aug(grads, params, theta_hat, gamma):
        return jax.tree.map(
            lambda g, p, th, gm, nb: (
                g.astype(jnp.float32)
                + 2.0 * rho * _degb(deg, p) * p.astype(jnp.float32)
                + gm
                - rho * (_degb(deg, th) * th + nb)),
            grads, params, theta_hat, gamma, nbr_sum)

    def dual(gamma, new_theta_hat):
        nbr_new = dual_fetch(new_theta_hat)
        new_gamma = jax.tree.map(
            lambda gm, th, nb: gm + rho * (_degb(deg, th) * th - nb),
            gamma, new_theta_hat, nbr_new)
        return new_gamma, cache[0], cache[1]

    return _Exchange(deg, nbr_sum, g_aug, dual)


def consensus_update(ccfg: ConsensusConfig, opt_cfg: OptConfig,
                     params, grads, state, comm=None, primal_solve=None,
                     participate=None, adjacency=None, alive=None,
                     joined=None):
    """params/grads: agent-stacked pytrees (N, ...). Returns
    (new_params, new_state, metrics).

    comm — a core.comm policy chain governing the broadcast (censor /
    quantize / drop); None = the legacy chain from ccfg's censor knobs.
    Numeric chain parameters may be traced arrays: the policy is array
    data, so threshold sweeps do not retrace the step.

    primal_solve — optional exact primal for the ADMM strategies:
    called as primal_solve(params, theta_hat, gamma, nbr_sum, deg) with
    nbr_sum = sum of neighbor theta_hat trees, replacing the one-step
    inexact optimizer update (grads and the optimizer state are then
    untouched). This is how the matrix-free CG primal runs distributed:
    the solve sees only agent-local trees plus the already-permuted
    neighbor sum, so it composes with any circulant topology.

    participate — optional (N,) bool gossip participation mask (ADMM
    strategies only): non-participating agents hold params / optimizer
    state / dual, are structurally silent in the broadcast (the chain's
    `active` mask — they pay zero bits, receivers keep the stale value),
    and integrate the dual drift delayed-but-correct on their next wake.
    The permutes still execute every round (SPMD is bulk-synchronous at
    the collective level; sleeping is value-masking, exactly like the
    censor semantics). An all-true mask is bitwise `participate=None`.

    adjacency — optional (N, N) dense weighted graph (ADMM strategies
    only): weighted degrees `sum_j w_ij` and per-leaf `A @ x` neighbor
    sums replace the circulant permutes + cache. This is the learned-
    collaboration-graph (personalization) hook: the graph may change per
    iteration, so the cached fetch — which belongs to the previous
    step's graph — is bypassed (and carried untouched).

    alive / joined — optional (N,) bool churn masks (ADMM strategies on
    the static ring): dead agents are zero-weighted out of every degree
    and neighbor sum (the cached fetch, unmasked and possibly stale
    across a churn event, is bypassed and carried untouched), and the
    rows flagged `joined` restart cold — zero primal / broadcast / dual
    and a fresh optimizer slot — exactly mirroring the simulator's
    `core.gossip` churn semantics."""
    step = state["step"] + 1
    metrics: dict[str, jax.Array] = {}
    dense = adjacency is not None
    if ccfg.offset_schedule and ccfg.strategy not in ("dkla", "coke",
                                                      "coke_et"):
        raise ValueError(
            "offset_schedule (time-varying topology) is implemented for "
            f"the ADMM strategies, not {ccfg.strategy!r}")
    if participate is not None and not ccfg.is_admm:
        raise ValueError(
            "gossip participation masking is implemented for the ADMM "
            f"strategies (dkla/coke/coke_et), not {ccfg.strategy!r}")
    if dense:
        if not ccfg.is_admm:
            raise ValueError(
                "a dense (learned) adjacency is implemented for the ADMM "
                f"strategies (dkla/coke/coke_et), not {ccfg.strategy!r}")
        if ccfg.use_fused_kernel:
            raise ValueError(
                "the fused coke_update kernel bakes the graph degree in "
                "as a static parameter; a dense adjacency requires "
                "use_fused_kernel=False")
        if ccfg.offset_schedule:
            raise ValueError(
                "offset_schedule and a dense adjacency are two competing "
                "definitions of the step's graph; pass one or the other")

    if ccfg.strategy == "cta":
        left, right = _ring_neighbors(params, ccfg.offsets)
        w = ccfg.mix_weight / len(ccfg.offsets)
        combined = jax.tree.map(
            lambda p, l, r: ((1 - ccfg.degree * w) * p.astype(jnp.float32)
                             + w * (l + r).astype(jnp.float32)).astype(p.dtype),
            params, left, right)
        updates, opt = jax.vmap(
            lambda g, s, p: opt_update(opt_cfg, g, s, p)
        )(grads, state["opt"], combined)
        new_params = apply_updates(combined, updates)
        n_agents = jax.tree.leaves(params)[0].shape[0]
        new_state = dict(state, opt=opt, step=step,
                         comms=state["comms"] + n_agents)
        return new_params, new_state, metrics

    # --- ADMM family (dkla / coke / coke_et) -------------------------------
    theta_hat, gamma = state["theta_hat"], state["gamma"]
    chain = ccfg.comm_chain() if comm is None else comm_mod.as_chain(comm)
    num_agents = jax.tree.leaves(params)[0].shape[0]
    opt0 = state["opt"]
    if joined is not None:
        # a (re)joining agent restarts cold: zero primal / broadcast /
        # dual rows and a fresh optimizer slot (core.gossip semantics)
        params, theta_hat, gamma, opt0 = _mask_rows(
            joined, jax.tree.map(jnp.zeros_like,
                                 (params, theta_hat, gamma, opt0)),
            (params, theta_hat, gamma, opt0))

    cache = (state["nbr_left"], state["nbr_right"])
    if ccfg.offset_schedule:
        if ccfg.use_fused_kernel:
            raise ValueError(
                "the fused coke_update kernel bakes the graph degree in as "
                "a static parameter; offset_schedule (time-varying "
                "topology) requires use_fused_kernel=False")
        variants = ccfg.offset_schedule
        graph_idx = (step - 1) % len(variants)
        degs = jnp.asarray([2.0 * len(v) for v in variants], jnp.float32)
        # the cached fetch belongs to the PREVIOUS step's graph — re-fetch
        # theta_hat^{k-1} neighbors under the graph active at step k
        left, right = _scheduled_neighbors(theta_hat, variants, graph_idx)
        x = _halves_exchange(
            ccfg.rho, degs[graph_idx], left, right,
            lambda nh: _scheduled_neighbors(nh, variants, graph_idx))
    elif dense:
        # learned weighted graph: (N,) degrees and matmul neighbor sums;
        # the circulant cache is stale under a per-iteration graph —
        # carried untouched (structurally present, never read)
        x = _summed_exchange(
            ccfg.rho, jnp.sum(adjacency, axis=1),
            _dense_neighbors(adjacency, theta_hat),
            lambda nh: _dense_neighbors(adjacency, nh), cache)
    elif alive is not None:
        if ccfg.use_fused_kernel:
            raise ValueError(
                "the fused coke_update kernel bakes the graph degree in "
                "as a static parameter; churn (a traced alive mask) "
                "requires use_fused_kernel=False")
        # churn-masked ring: per-agent alive-weighted degrees, masked
        # permute sums, stale cache bypassed (same policy as dense)
        alive_f = alive.astype(jnp.float32)
        deg_l, deg_r = _ring_neighbors(alive_f, ccfg.offsets)
        x = _summed_exchange(
            ccfg.rho, deg_l + deg_r,
            _alive_ring_sum(theta_hat, alive_f, ccfg.offsets),
            lambda nh: _alive_ring_sum(nh, alive_f, ccfg.offsets), cache)
    else:
        # neighbors' theta_hat^{k-1}: served from the cache filled by the
        # previous step's dual-update fetch — no permute here
        x = _halves_exchange(
            ccfg.rho, ccfg.degree, cache[0], cache[1],
            lambda nh: _ring_neighbors(nh, ccfg.offsets))

    # primal update (21a): exact when the caller supplies a solve (the
    # matrix-free CG path), otherwise one optimizer step on the augmented
    # Lagrangian gradient
    #   g_aug = g_local + 2 rho deg theta + gamma - rho (deg theta_hat + sum_n theta_hat_n)
    if primal_solve is not None:
        new_params = primal_solve(params, theta_hat, gamma, x.nbr_sum,
                                  x.deg)
        opt = opt0
    else:
        if ccfg.use_fused_kernel:
            from repro.kernels.coke_update.ops import coke_update_pytree
            half = jax.tree.map(lambda s: 0.5 * s, x.nbr_sum)
            g_aug, _ = coke_update_pytree(
                params, theta_hat, gamma, grads, half, half,
                rho=ccfg.rho, deg=x.deg)
        else:
            g_aug = x.g_aug(grads, params, theta_hat, gamma)
        updates, opt = jax.vmap(
            lambda g, s, p: opt_update(opt_cfg, g, s, p)
        )(g_aug, opt0, params)
        new_params = apply_updates(params, updates)

    # gossip: sleepers hold their primal iterate and optimizer state
    if participate is not None:
        new_params = _mask_rows(participate, new_params, params)
        opt = _mask_rows(participate, opt, opt0)

    # communication policy (censor (19)/(20) / quantize / drop) over the
    # flattened agent-stacked message, with stale-value fallback — shared
    # decision code with the simulator (cross-backend parity contract)
    comm_state = chain.ensure_state(state.get("comm"), num_agents)
    new_theta_hat, send, comm_state = comm_mod.apply_tree(
        chain, new_params, theta_hat, step, comm_state,
        active=participate)

    # dual (21b) with theta_hat^k values — the step's ONLY neighbor fetch
    # on a static topology (2 permutes); cached for the next primal update
    new_gamma, hat_l, hat_r = x.dual(gamma, new_theta_hat)
    # gossip: sleepers' duals freeze (delayed-but-correct — the next wake
    # integrates (21b) against the then-current broadcast values)
    if participate is not None:
        new_gamma = _mask_rows(participate, new_gamma, gamma)

    metrics["send_frac"] = jnp.mean(send.astype(jnp.float32))
    metrics["bits"] = jnp.sum(comm_state.bits)
    new_state = dict(state, opt=opt, step=step,
                     comms=state["comms"] + jnp.sum(send.astype(jnp.int32)),
                     theta_hat=new_theta_hat, gamma=new_gamma,
                     nbr_left=hat_l, nbr_right=hat_r, comm=comm_state)
    return new_params, new_state, metrics


def init_stream_state(ccfg: ConsensusConfig, theta0: jax.Array,
                      comm=None) -> dict[str, Any]:
    """State carried by `stream_update` alongside the (N, D) params:
    last-broadcast theta_hat, duals, the neighbor cache (exact rolls of
    theta_hat — agents may start unequal under a warm start), and the
    policy's persistent CommState."""
    chain = comm_mod.as_chain(comm)
    theta_hat = theta0.astype(jnp.float32)
    left, right = _ring_neighbors(theta_hat, ccfg.offsets)
    return {
        "step": jnp.zeros((), jnp.int32),
        "comms": jnp.zeros((), jnp.int32),
        "theta_hat": theta_hat,
        "gamma": jnp.zeros_like(theta_hat),
        "nbr_left": left,
        "nbr_right": right,
        "comm": chain.init_state(theta0.shape[0]),
    }


def stream_update(ccfg: ConsensusConfig, params, state, feats, labels, *,
                  lam: float, lr: float, eta: float | None = None,
                  comm=None, participate=None, adjacency=None,
                  alive=None, joined=None):
    """One streaming (online) round on the ring runtime — the
    `consensus_update`-style hook behind `fit_stream`'s spmd backend.

    params: {"theta": (N, D)}; feats/labels: the round's fresh minibatch
    (N, b, D)/(N, b). Fresh-minibatch gradient, gradient (eta=None) or
    linearized-ADMM (eta=float, per QC-ODKLA) primal, then the SAME
    `core.comm` broadcast decision code as the simulator's
    `core.online.stream_step` — send decisions and bit accounting match
    across backends — with the dual-update neighbor fetch cached for the
    next primal (2 permutes per round on a static circulant).

    participate — optional (N,) bool gossip participation mask, with the
    same semantics as `consensus_update`: sleepers hold theta and gamma,
    are structurally silent in the broadcast (zero bits), and catch up on
    the dual drift at their next wake. The round's minibatch still flows
    (the regret sample is measured on every agent's incoming data whether
    or not it woke up to learn from it).

    adjacency — optional (N, N) dense weighted graph (the learned-
    collaboration-graph hook, same semantics as `consensus_update`):
    weighted degrees and `A @ x` neighbor sums replace the circulant
    permutes + cache; the expressions mirror the simulator's
    `core.online.stream_step` bit-for-bit.

    alive/joined — optional (N,) bool churn masks, same semantics as
    `consensus_update`: dead agents contribute nothing to the masked
    neighbor sums (alive-weighted degrees), joiners restart cold.

    Returns (new_params, new_state, metrics) with metrics carrying the
    pre-update instantaneous MSE (the regret sample) and cumulative bits.
    """
    theta = params["theta"]
    theta_hat, gamma = state["theta_hat"], state["gamma"]
    N = theta.shape[0]
    dense = adjacency is not None
    rho = ccfg.rho
    chain = comm_mod.as_chain(comm)
    k = state["step"] + 1

    if joined is not None:
        theta, theta_hat, gamma = _mask_rows(
            joined, jax.tree.map(jnp.zeros_like, (theta, theta_hat, gamma)),
            (theta, theta_hat, gamma))

    preds = jnp.einsum("nbd,nd->nb", feats, theta)
    inst_mse = jnp.mean((labels - preds) ** 2)

    # streaming augmented-Lagrangian gradient — the simulator's nbr_sum
    # (adjacency @ theta_hat) served from the cached permutes, or computed
    # dense under a learned graph
    resid = preds - labels
    g_data = 2.0 * jnp.einsum("nb,nbd->nd", resid, feats) / feats.shape[1]
    if dense:
        deg = jnp.sum(adjacency, axis=1)[:, None]   # (N, 1) weighted
        nbr_sum = adjacency @ theta_hat
    elif alive is not None:
        # churn-masked ring: alive-weighted degrees + masked permute sums
        # (stale circulant cache bypassed — same policy as dense)
        alive_f = alive.astype(jnp.float32)
        deg_l, deg_r = _ring_neighbors(alive_f, ccfg.offsets)
        deg = (deg_l + deg_r)[:, None]              # (N, 1) per-agent
        nbr_sum = _alive_ring_sum(theta_hat, alive_f, ccfg.offsets)
    else:
        deg = ccfg.degree       # static scalar: circulant topologies only
        nbr_sum = state["nbr_left"] + state["nbr_right"]
    g = (g_data + (2.0 * lam / N) * theta
         + 2.0 * rho * deg * theta
         + gamma
         - rho * (deg * theta_hat + nbr_sum))
    if eta is None:
        new_theta = theta - lr * g
    else:
        new_theta = theta - g / (eta + 2.0 * rho * deg)

    # gossip: sleepers hold their primal iterate
    if participate is not None:
        new_theta = _mask_rows(participate, new_theta, theta)

    # policy-governed broadcast: identical decision code and CommState
    # evolution as the simulator path (chain.apply on the (N, D) message)
    comm_state = chain.ensure_state(state.get("comm"), N)
    new_theta_hat, send, comm_state = chain.apply(new_theta, theta_hat, k,
                                                  comm_state,
                                                  active=participate)

    # dual with theta_hat^k — the round's ONLY neighbor fetch; cached for
    # the next primal update (dense: recomputed matmul, stale cache
    # carried untouched)
    if dense:
        new_gamma = gamma + rho * (deg * new_theta_hat
                                   - adjacency @ new_theta_hat)
        hat_l, hat_r = state["nbr_left"], state["nbr_right"]
    elif alive is not None:
        new_gamma = gamma + rho * (
            deg * new_theta_hat
            - _alive_ring_sum(new_theta_hat, alive_f, ccfg.offsets))
        hat_l, hat_r = state["nbr_left"], state["nbr_right"]
    else:
        hat_l, hat_r = _ring_neighbors(new_theta_hat, ccfg.offsets)
        new_gamma = gamma + rho * (deg * new_theta_hat - hat_l - hat_r)
    # gossip: sleepers' duals freeze (delayed-but-correct)
    if participate is not None:
        new_gamma = _mask_rows(participate, new_gamma, gamma)

    metrics = {"instant_mse": inst_mse,
               "bits": jnp.sum(comm_state.bits)}
    new_state = dict(state, step=k,
                     comms=state["comms"] + jnp.sum(send.astype(jnp.int32)),
                     theta_hat=new_theta_hat, gamma=new_gamma,
                     nbr_left=hat_l, nbr_right=hat_r, comm=comm_state)
    return {"theta": new_theta}, new_state, metrics


def local_update(opt_cfg: OptConfig, params, grads, state):
    """Purely local step (no collectives over the agent axis) — the censored
    rounds of the event-triggered coke_et strategy."""
    updates, opt = jax.vmap(
        lambda g, s, p: opt_update(opt_cfg, g, s, p)
    )(grads, state["opt"], params)
    return apply_updates(params, updates), dict(
        state, opt=opt, step=state["step"] + 1)


def consensus_gap(params) -> jax.Array:
    """max_i ||theta_i - mean theta|| — the Fig.-1 functional-consensus
    diagnostic, for agent-stacked params."""
    mean = jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), 0,
                                           keepdims=True), params)
    diff = jax.tree.map(lambda p, m: p.astype(jnp.float32) - m, params, mean)
    return jnp.max(_agent_norms(diff))
