"""Distribution substrate: meshes, divisibility-aware sharding, consensus DP."""
from repro.distributed import consensus, sharding  # noqa: F401
from repro.distributed.consensus import ConsensusConfig  # noqa: F401
