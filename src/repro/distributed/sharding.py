"""Divisibility-aware sharding rules.

Head counts / vocab sizes of the assigned archs are not uniformly divisible
by the 16-way `model` axis (internvl2 has 14 heads, minicpm3 has 40, GQA KV
is often 8). The rule-set here shards a dim over a mesh axis IFF divisible,
else falls back (replicate, or for KV caches shard the cache-length dim —
sequence-parallel KV). This guarantees every (arch x shape x mesh) lowers;
the roofline table then shows the replication cost where it occurs.

Naming-based rules walk the param pytree with tree_map_with_path; params
under a stacked layer collection ("blocks", "encoder", "decoder") carry
leading scan dims that are never sharded (optionally FSDP-sharded over the
batch axes — a hillclimb lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.common import ModelConfig


def _div(size: int, mesh, axis: str | tuple[str, ...] | None):
    """axis if size divides the mesh extent, else None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        import math
        extent = math.prod(mesh.shape[a] for a in axis)
    else:
        extent = mesh.shape[axis]
    return axis if size % extent == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# --- per-tensor rules ------------------------------------------------------

def _leaf_spec(cfg: ModelConfig, mesh, path: str, shape: tuple[int, ...],
               n_stack: int, fsdp: bool) -> P:
    """PartitionSpec for the *unstacked* trailing dims; `n_stack` leading
    scan dims get None (or FSDP over batch axes on the first stack dim)."""
    m = "model"
    name = path.split("/")[-1]
    dims = shape[n_stack:]

    def spec(*parts):
        lead = [None] * n_stack
        parts = list(parts)
        if fsdp:
            # ZeRO-style: shard the largest still-unsharded weight dim over
            # the batch axes (falls back to the stack dim when divisible)
            import math
            ba = batch_axes(mesh)
            extent = math.prod(mesh.shape[a] for a in ba) if ba else 0
            if extent:
                cands = [(dims[i], i) for i in range(len(parts))
                         if parts[i] is None and dims[i] % extent == 0
                         and dims[i] >= extent]
                if cands:
                    _, idx = max(cands)
                    parts[idx] = ba
                elif n_stack >= 1 and shape[0] % extent == 0:
                    lead[0] = ba
        return P(*lead, *parts)

    if name in ("embed",):                       # (Vp, d)
        return spec(_div(dims[0], mesh, m), None)
    if name == "lm_head":                        # (d, Vp)
        return spec(None, _div(dims[1], mesh, m))
    if name in ("wq", "wk", "wv"):               # (d, H, Dh)
        return spec(None, _div(dims[1], mesh, m), None)
    if name == "wo":                             # (H, Dh, d)
        return spec(_div(dims[0], mesh, m), None, None)
    if name == "wq_b" or name == "wkv_b":        # (r, H, e)
        return spec(None, _div(dims[1], mesh, m), None)
    if name in ("wq_a", "wkv_a"):                # (d, r) small latents
        return spec(None, None)
    if name in ("w_gate", "w_up"):
        if len(dims) == 3:                       # MoE experts (E, d, f)
            e = _div(dims[0], mesh, m)
            return spec(e, None, _div(dims[2], mesh, m) if e is None else None)
        return spec(None, _div(dims[1], mesh, m))   # dense (d, f)
    if name == "w_down":
        if len(dims) == 3:                       # (E, f, d)
            e = _div(dims[0], mesh, m)
            return spec(e, _div(dims[1], mesh, m) if e is None else None, None)
        return spec(_div(dims[0], mesh, m), None)   # (f, d)
    if name in ("shared_gate", "shared_up"):     # (d, fs)
        return spec(None, _div(dims[1], mesh, m))
    if name == "shared_down":                    # (fs, d)
        return spec(_div(dims[0], mesh, m), None)
    if name in ("w_z", "w_x"):                   # ssm (d, d_inner)
        return spec(None, _div(dims[1], mesh, m))
    if name == "w_dt":                           # ssm (d, H)
        return spec(None, _div(dims[1], mesh, m))
    if name == "w_bc":                           # ssm (d, 2N) — B/C shared
        return spec(None, None)
    if name == "conv_x":                         # ssm (W, d_inner)
        return spec(None, _div(dims[1], mesh, m))
    if name in ("conv_bc", "conv_bx", "conv_bbc"):
        if name == "conv_bx":                    # (d_inner,)
            return spec(_div(dims[0], mesh, m))
        return spec(*([None] * len(dims)))
    if name == "norm" and len(dims) == 1:        # ssm gated norm (d_inner,)
        return spec(_div(dims[0], mesh, m))
    if name == "out_proj":                       # ssm (d_inner, d)
        return spec(_div(dims[0], mesh, m), None)
    if name == "router":                         # (d, E) fp32, small
        return spec(None, None)
    # norms, biases, conv, A_log, D, dt_bias, scalars -> replicated
    return spec(*([None] * len(dims)))


_STACKED_ROOTS = ("blocks", "encoder", "decoder")


def _stack_depth(cfg: ModelConfig, path: str) -> int:
    parts = path.split("/")
    root = next((p for p in parts if p in _STACKED_ROOTS), None)
    if root is None:
        return 0
    if root == "blocks" and cfg.arch_type == "hybrid":
        return 2  # (groups, every, ...)
    return 1


def param_specs(cfg: ModelConfig, shapes_pytree, mesh, fsdp: bool = False):
    """PartitionSpec pytree matching a params (or ShapeDtypeStruct) pytree."""
    def rule(path, leaf):
        ps = _path_str(path)
        return _leaf_spec(cfg, mesh, ps, leaf.shape, _stack_depth(cfg, ps),
                          fsdp)
    return jax.tree_util.tree_map_with_path(rule, shapes_pytree)


def param_shardings(cfg: ModelConfig, shapes_pytree, mesh,
                    fsdp: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, shapes_pytree, mesh, fsdp))


# --- batch / serve-state specs ---------------------------------------------

def batch_specs(cfg: ModelConfig, specs_pytree, mesh):
    """Token/embedding batches: leading batch dim over the batch axes (iff
    divisible), everything else replicated."""
    ba = batch_axes(mesh)

    def rule(leaf):
        b = _div(leaf.shape[0], mesh, ba) if leaf.ndim >= 1 else None
        return P(b, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(rule, specs_pytree)


def decode_state_specs(cfg: ModelConfig, state_pytree, mesh):
    """Serve-state sharding: (L, B, C, KV, Dh) caches shard batch over the
    batch axes and KV-heads over `model` — falling back to sequence-parallel
    cache (shard C over model) when the head count doesn't divide."""
    ba = batch_axes(mesh)

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        shape = leaf.shape
        if name == "slot_positions":                   # (L, C) or (G, C)
            return P(*([None] * leaf.ndim))
        if name in ("k", "v"):                         # (L, B, C, KV, Dh)
            b = _div(shape[1], mesh, ba)
            kv = _div(shape[3], mesh, "model")
            c = None if kv else _div(shape[2], mesh, "model")
            return P(None, b, c, kv, None)
        if name in ("ckv", "krope"):                   # (L, B, C, r)
            b = _div(shape[1], mesh, ba)
            c = _div(shape[2], mesh, "model")
            return P(None, b, c, None)
        if name in ("cross_k", "cross_v"):             # (L, B, S_enc, KV, Dh)
            b = _div(shape[1], mesh, ba)
            kv = _div(shape[3], mesh, "model")
            c = None if kv else _div(shape[2], mesh, "model")
            return P(None, b, c, kv, None)
        if name == "conv_x":                           # (.., B, W-1, di)
            lead = leaf.ndim - 3
            b = _div(shape[lead], mesh, ba)
            return P(*([None] * lead), b, None,
                     _div(shape[-1], mesh, "model"))
        if name == "conv_bc":                          # (.., B, W-1, 2N)
            lead = leaf.ndim - 3
            b = _div(shape[lead], mesh, ba)
            return P(*([None] * lead), b, None, None)
        if name == "state":                            # (.., B, H, P, N)
            lead = leaf.ndim - 4
            b = _div(shape[lead], mesh, ba)
            return P(*([None] * lead), b,
                     _div(shape[lead + 1], mesh, "model"), None, None)
        # fallback: replicate
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, state_pytree)


# --- consensus feature sharding (the big-D kernel-learning path) -----------

def feature_spec(shape: tuple[int, ...], mesh, num_agents: int) -> P:
    """PartitionSpec for one agent-stacked consensus leaf.

    The rule for the kernel workload's (N, ..., D) trees (theta, theta_hat,
    gamma, feats, optimizer slots): shard the TRAILING feature dim over the
    "model" axis iff divisible — that is what turns a (N, D) tree into
    (N, D/shards) per device — and the leading agent axis over the batch
    axes iff it is the agent axis (size N) and divisible. Everything the
    rule cannot prove agent-stacked (policy PRNG keys, scalar counters,
    (D,)-vectors like the oracle) replicates: under GSPMD the censor norm
    sum over the sharded feature dim then reduces with a single psum, and
    the jnp.roll neighbor exchange stays a collective-permute over the
    batch axes.
    """
    ndim = len(shape)
    if ndim == 0:
        return P()
    ba = batch_axes(mesh)
    lead = _div(shape[0], mesh, ba) if (ba and shape[0] == num_agents) \
        else None
    if ndim == 1:
        return P(lead)
    feat = _div(shape[-1], mesh, "model") if "model" in mesh.axis_names \
        else None
    return P(lead, *([None] * (ndim - 2)), feat)


def feature_specs(tree, mesh, num_agents: int):
    """feature_spec over a pytree (consensus carry, Problem, model params)."""
    return jax.tree.map(lambda leaf: feature_spec(leaf.shape, mesh,
                                                  num_agents), tree)


def shard_features(tree, mesh, num_agents: int):
    """Place every leaf of an agent-stacked tree with its feature-sharded
    layout. jit carries preserve input shardings, so placing the fit loop's
    initial carry (and the Problem) once pins the whole scan to the
    (N, D/shards)-per-device layout."""
    return jax.tree.map(
        lambda leaf: jax.device_put(
            leaf, NamedSharding(mesh, feature_spec(leaf.shape, mesh,
                                                   num_agents))), tree)


def shard_problem(problem, mesh):
    """Feature-shard an `admm.Problem`: feats (N, Ti, D) carry the feature
    dim on "model" and the agent dim on the batch axes; labels (N, Ti) and
    adjacency (N, N) only shard the agent dim — their trailing dims are
    samples/agents, NOT features, so the generic trailing-dim rule must not
    touch them (a mis-sharded labels array would force a reshard inside
    every phi.T @ y)."""
    import dataclasses as _dc

    N = problem.num_agents
    ba = batch_axes(mesh)
    lead = _div(N, mesh, ba) if ba else None
    feat = _div(problem.feature_dim, mesh, "model") \
        if "model" in mesh.axis_names else None
    put = lambda x, spec: jax.device_put(x, NamedSharding(mesh, spec))  # noqa: E731
    return _dc.replace(
        problem,
        feats=put(problem.feats, P(lead, None, feat)),
        labels=put(problem.labels, P(lead, None)),
        adjacency=put(problem.adjacency, P(lead, None)))


def theta_stack_spec(shape: tuple[int, ...], mesh) -> P:
    """PartitionSpec for the many-model serving `(M, D)` resident-theta
    stack (`serve.ThetaStore`).

    The slot axis M stays REPLICATED — the multi-tenant scorer gathers
    per-request rows with dynamic indices, and a batch-sharded slot axis
    would turn every gather into an all-to-all — while the trailing
    feature dim shards over the "model" axis iff divisible, matching
    `feature_spec`'s layout for theta so a store faulted from a D-sharded
    fit never needs a replicated feature axis on any device. phi(x) @
    theta rows then contract the sharded dim with one psum under GSPMD,
    exactly like the single-model serving path."""
    feat = _div(shape[-1], mesh, "model") if "model" in mesh.axis_names \
        else None
    return P(*([None] * (len(shape) - 1)), feat)


def shard_theta_stack(stack, mesh):
    """Place an (M, D) theta stack with its serving layout."""
    return jax.device_put(
        stack, NamedSharding(mesh, theta_stack_spec(stack.shape, mesh)))


def step_in_specs(cfg: ModelConfig, kind: str, specs: dict, mesh):
    """Input PartitionSpecs for a dry-run step of the given kind."""
    if kind in ("train", "prefill"):
        return batch_specs(cfg, specs, mesh)
    ba = batch_axes(mesh)
    return {
        "token": P(_div(specs["token"].shape[0], mesh, ba), None),
        "position": P(),
        "state": decode_state_specs(cfg, specs["state"], mesh),
    }
