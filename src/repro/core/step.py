"""The step-composition layer: ONE per-iteration skeleton for the whole
solver family.

Every algorithm in this repo — DKLA / COKE (batch ADMM), the online
variants and QC-ODKLA (streaming), their gossip forms, and the
personalized learned-graph forms — iterates the same six named stages:

    featurize    minibatch predictions / residual gradient (streaming
                 only; batch solvers read pre-featurized Problem.feats)
    primal       the (21a) argmin (closed form / CG / gradient) or the
                 streaming augmented-Lagrangian step
    comm_decide  who speaks: gossip participation sampling (and, inside
                 the comm chain, the censor/quantize/drop decisions)
    exchange     the neighbor view: dense `A @ x` on the simulator,
                 NeighborTable gathers under gossip, ring permutes on the
                 spmd backend, a per-k scheduled graph under topology
    dual         the (21b) dual ascent against the fresh broadcasts
    record       transmission / bit accounting

Before this layer the skeleton was hand-wired once per (backend × exec ×
workload) cell; now `run_step` owns the ordering and the masking/dual/
record tail, and each solver step is a thin *stage assembly*: an
`exchange` stage producing a `GraphView`, a `primal` stage, and an
optional `comm_decide` stage.

Bit-exactness contract: `run_step` computes the exact expressions the
hand-written steps computed, in the same order — `chain.ensure_state` is
value-pure (state restructuring, no RNG, no float math), so its position
relative to the primal is free; everything that touches floats or the
PRNG is ordered identically. All existing parity pins (legacy `admm.run`,
cross-backend, degenerate gossip, personalization warmup prefix) ride on
this.

Carry contract: the state is any NamedTuple with the six COKEState /
OnlineState fields `(theta, theta_hat, gamma, step, comms, comm)`,
agent-stacked on the leading axis; `run_step` rebuilds the same type.
Stages communicate only through explicit values (the GraphView and the
(theta0, theta_hat0, gamma0) snapshot) — no hidden module state, which is
what lets `sweep()` vmap whole programs and the backends swap stages
without re-deriving the skeleton.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as comm_mod

#: fold-in tag separating the participation stream from the comm stages'
#: per-round streams (Chain.apply folds the stage *index*; this sentinel
#: can never collide with one)
PARTICIPATION_TAG = np.uint32(0x9E3779B1)


def participation_mask(key: jax.Array, k, num_agents: int,
                       plan, alive: jax.Array | None = None) -> jax.Array:
    """(N,) bool — who computes and broadcasts this round.

    key is the chain-level `CommState.key`: folding (iteration k,
    PARTICIPATION_TAG, the rate's f32 bit pattern) gives a stream that is
    (a) independent of the comm stages' draws, (b) per-cell under sweep's
    vmap (the chain key already folds every policy parameter), and (c)
    identical on every backend carrying the same CommState. Straggler
    slowdowns scale the *threshold/score*, not the stream — common random
    numbers across slowdown scenarios: in Bernoulli mode the acceptance
    probability divides by the slowdown, in fixed-size (top-k) mode the
    draw is multiplied by it so slowed agents sink in the ranking while
    exactly `size` agents still fire each round. slowdown=None is
    bit-identical to the unscaled draw in both modes. rate = 1.0 is
    exactly the all-ones mask (uniform draws live in [0, 1)), the
    degeneracy contract."""
    r = jax.random.fold_in(key, jnp.asarray(k, jnp.uint32))
    r = jax.random.fold_in(r, PARTICIPATION_TAG)
    r = comm_mod._fold_value(r, plan.participation)
    u = jax.random.uniform(r, (num_agents,))
    if plan.size is not None:
        score = u if plan.slowdown is None else u * plan.slowdown
        if alive is not None:
            score = jnp.where(alive, score, jnp.inf)
        _, sel = jax.lax.top_k(-score, plan.size)
        m = jnp.zeros((num_agents,), bool).at[sel].set(True)
    else:
        p = jnp.asarray(plan.participation, jnp.float32)
        if plan.slowdown is not None:
            p = jnp.minimum(p / plan.slowdown, 1.0)
        m = u < p
    if alive is not None:
        m = m & alive
    return m


def _mask_rows(m: jax.Array, new, old):
    """Row-select over agent-stacked pytrees: agent i's leaves take `new`
    iff m[i]; scalar leaves pass through. With an all-true mask this is
    bitwise `new` — the degenerate-gossip contract."""
    def sel(a, b):
        if a.ndim == 0:
            return a
        return jnp.where(m.reshape(m.shape + (1,) * (a.ndim - 1)), a, b)
    return jax.tree.map(sel, new, old)


# ---------------------------------------------------------------------------
# The exchange stage's product: one iteration's view of the graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphView:
    """What one iteration sees of the consensus graph: per-agent (N,)
    degrees and a neighbor-sum operator, plus (under churn) the liveness
    mask and the rows that (re)joined this iteration, and (under a
    topology schedule with the closed-form primal) the per-k Cholesky
    factor stack."""

    deg: jax.Array                              # (N,) weighted degrees
    nbr_sum: Callable[[jax.Array], jax.Array]   # x (N, ...) -> sum_n w x_n
    alive: jax.Array | None = None              # (N,) bool liveness
    joined: jax.Array | None = None             # (N,) bool cold (re)joiners
    chol: jax.Array | None = None               # (N, D, D) resolved factors


def dense_view(adjacency: jax.Array, deg: jax.Array | None = None,
               chol: jax.Array | None = None) -> GraphView:
    """Dense (possibly weighted / learned) graph: `A @ x` neighbor sums."""
    d = jnp.sum(adjacency, axis=1) if deg is None else deg
    return GraphView(deg=d, nbr_sum=lambda x: adjacency @ x, chol=chol)


def table_view(table, plan, k) -> GraphView:
    """Padded NeighborTable gathers under a gossip plan: alive-weighted
    degrees and sums, never materializing (N, N); `joined` marks the rows
    whose churn event fired at exactly iteration k."""
    alive = plan.alive_at(k)
    joined = None
    if plan.has_churn:
        joined = alive & ~plan.alive_at(k - 1)
    return GraphView(deg=table.degrees(alive),
                     nbr_sum=lambda x: table.nbr_sum(x, alive),
                     alive=alive, joined=joined)


def sampled_stage(plan) -> Callable:
    """The gossip comm_decide stage: CommState-keyed participation
    sampling (masked to the live rows under churn)."""
    def stage(key, k, g: GraphView):
        return participation_mask(key, k, g.deg.shape[0], plan, g.alive)
    return stage


def stream_primal(feats: jax.Array, labels: jax.Array, *, lam: float,
                  rho: float, lr: float, eta: float | None) -> Callable:
    """The streaming featurize+primal stage shared by online-DKLA/COKE
    (eta=None: one gradient step of size lr) and QC-ODKLA (eta=float: the
    linearized-ADMM closed form, implemented in the same subtractive form
    so the two modes share every other float op). Emits the pre-update
    instantaneous MSE — the online-protocol regret sample."""
    def stage(k, g: GraphView, theta0, theta_hat0, gamma0, nbr_hat):
        N = feats.shape[0]
        deg = g.deg
        preds = jnp.einsum("nbd,nd->nb", feats, theta0)
        inst_mse = jnp.mean((labels - preds) ** 2)
        resid = preds - labels
        g_data = (2.0 * jnp.einsum("nb,nbd->nd", resid, feats)
                  / feats.shape[1])
        grad = (g_data + (2.0 * lam / N) * theta0
                + 2.0 * rho * deg[:, None] * theta0
                + gamma0
                - rho * (deg[:, None] * theta_hat0 + nbr_hat))
        if eta is None:
            theta_new = theta0 - lr * grad
        else:
            theta_new = theta0 - grad / (eta + 2.0 * rho * deg[:, None])
        return theta_new, {"inst_mse": inst_mse}
    return stage


# ---------------------------------------------------------------------------
# The step program and its executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepProgram:
    """One per-iteration program: the comm chain, the dual stepsize, and
    the three substitutable stages. `exchange(state, k)` resolves the
    iteration's GraphView; `primal(k, g, theta0, theta_hat0, gamma0,
    nbr_hat)` returns (theta_new, extras); `comm_decide(key, k, g)` — if
    set — returns the (N,) participation mask (None = synchronous: every
    agent updates, `chain.apply` runs unmasked and the trace is identical
    to the pre-refactor synchronous steps). `primal_owns_exchange=True`
    declares that the primal stage fetches its own neighbor view of
    theta_hat (the fused megakernel reads the ring-rolled rows inside the
    pallas_call), so `run_step` skips the pre-primal `nbr_sum` and passes
    nbr_hat=None."""

    chain: Any
    rho: Any
    exchange: Callable[[Any, Any], GraphView]
    primal: Callable
    comm_decide: Callable | None = None
    primal_owns_exchange: bool = False


def run_step(program: StepProgram, state):
    """Execute one iteration of `program` on a (theta, theta_hat, gamma,
    step, comms, comm) carry; returns (new_state, extras) with extras the
    primal stage's auxiliary outputs (e.g. the streaming regret sample)."""
    chain = program.chain
    k = state.step + 1
    comm_state = chain.ensure_state(state.comm, state.theta.shape[0])
    g = program.exchange(state, k)

    theta0, theta_hat0, gamma0 = state.theta, state.theta_hat, state.gamma
    if g.joined is not None:
        # a (re)joining agent restarts cold: zero primal/broadcast/dual
        theta0, theta_hat0, gamma0 = _mask_rows(
            g.joined, jax.tree.map(jnp.zeros_like, (theta0, theta_hat0,
                                                    gamma0)),
            (theta0, theta_hat0, gamma0))

    nbr_hat = (None if program.primal_owns_exchange
               else g.nbr_sum(theta_hat0))
    theta_new, extras = program.primal(k, g, theta0, theta_hat0, gamma0,
                                       nbr_hat)

    if program.comm_decide is not None:
        # gossip: sleepers hold their primal iterate, are structurally
        # silent in the broadcast (zero bits), and their duals freeze
        # (delayed-but-correct — the next wake integrates (21b) against
        # the then-current broadcast values)
        m = program.comm_decide(comm_state.key, k, g)
        theta = _mask_rows(m, theta_new, theta0)
    else:
        m = None
        theta = theta_new

    theta_hat, send, comm_state = chain.apply(theta, theta_hat0, k,
                                              comm_state, active=m)

    # dual (21b): gamma_i += rho * sum_n (theta_hat_i - theta_hat_n)
    nbr_new = g.nbr_sum(theta_hat)
    gamma = gamma0 + program.rho * (g.deg[:, None] * theta_hat - nbr_new)
    if m is not None:
        gamma = _mask_rows(m, gamma, gamma0)

    new_state = type(state)(
        theta=theta, theta_hat=theta_hat, gamma=gamma, step=k,
        comms=state.comms + jnp.sum(send.astype(jnp.int32)),
        comm=comm_state)
    return new_state, extras
