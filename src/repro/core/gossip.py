"""Asynchronous gossip execution — the second execution semantics of the
solver registry.

Every solver in this repo was born bulk-synchronous: each iteration, ALL
agents compute and ALL agents exchange. Real decentralized traffic is not
like that — Koppel et al. (arXiv 1710.04062) and the paper's own
time-varying-network discussion describe the regime where, per tick, only
a *sampled subset* of agents wakes up, computes, and gossips with its
neighbors, while everyone else holds state and neighbors are served stale
values. This module implements that regime as `FitConfig(exec="gossip")`:

  * **participation sampling** — a Bernoulli(rate) or fixed-size subset of
    agents performs the primal step and broadcasts each iteration. The
    draw comes from the `CommState` chain-level PRNG key (folded with the
    iteration and a dedicated stage tag), NOT a static seed: under
    `sweep()`'s vmap every grid cell carries its own independent
    participation schedule, identical cells stay bit-identical, and the
    simulator / spmd backends derive the SAME masks from the same state
    (so comms/bits agree exactly across backends).
  * **stale-neighbor fallback** — non-participants neither transmit nor
    pay bits; their last broadcast (`theta_hat`) keeps serving neighbor
    reads, generalizing the one-theta_hat-per-agent stale-value machinery
    `Drop` already relies on.
  * **delayed-but-correct duals** — a non-participant's dual variable is
    frozen; when it next participates, the (21b) update runs against the
    *current* broadcast values, accumulating the drift it slept through
    exactly once.
  * **churn** — a `ChurnSchedule` scripts straggler slowdowns and agent
    join/leave events at scheduled iterations. Liveness is traced data
    (an event-indexed alive stack), so churn runs inside the compiled
    scan: a leaver is removed from every neighbor sum and degree, a
    (re)joiner restarts from zero state, and surviving agents'
    trajectories are unperturbed except through the graph.

Scaling contract: the simulator gossip path is a vectorized masked update
over the agent-stacked state — no Python loop over N, and **no dense
(N, N) adjacency is ever read or materialized** (`NeighborTable` gathers
over a padded (N, K) neighbor-index table), so N in the thousands fits.
Pinned by jaxpr inspection in tests/test_gossip.py.

Degeneracy contract: at participation = 1.0 with no churn and no
stragglers, every masked update reduces to the synchronous step —
bit-identical to `exec="sync"` on deg-2 graphs (ring), where the
gather-sum and the dense `A @ x` accumulate identical partial sums, and
float-close on denser graphs (summation-order ulps only). The conformance
harness (`tests/conftest.py::assert_gossip_degenerate`) pins the
bit-identical form on simulator and spmd.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm as comm_mod
from repro.core import step as step_mod
from repro.core.admm import COKEState, Problem, _primal_stage
from repro.core.online import OnlineState
from repro.core.step import (PARTICIPATION_TAG,  # noqa: F401 (re-export)
                             _mask_rows, participation_mask)

EXEC_MODES = ("sync", "gossip")


# ---------------------------------------------------------------------------
# NeighborTable: the sparse neighbor view (no dense (N, N) on the hot path)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("idx", "nmask"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class NeighborTable:
    """Padded neighbor-index form of an undirected graph: row i lists
    agent i's neighbors in ascending index order, padded to the max
    degree. All neighbor reductions are gathers over this table —
    O(N * K * D), never an (N, N) matmul — which is what lets the
    simulator hold thousands of agents.

    On deg-2 rows the two-term gather-sum is bit-identical to the dense
    `A @ x` row (adding zeros and reordering a two-term sum are exact),
    the property the ring-graph degeneracy pin leans on."""

    idx: jax.Array    # (N, K) int32 neighbor indices (0-padded)
    nmask: jax.Array  # (N, K) float32: 1.0 real neighbor, 0.0 padding

    @property
    def num_agents(self) -> int:
        return self.idx.shape[0]

    @property
    def max_degree(self) -> int:
        return self.idx.shape[1]

    @classmethod
    def from_adjacency(cls, adjacency) -> "NeighborTable":
        """Host-side build from a dense (N, N) adjacency (numpy); the
        dense form never reaches the compiled step."""
        A = np.asarray(adjacency)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"adjacency must be square, got {A.shape}")
        N = A.shape[0]
        rows = [np.nonzero(A[i])[0] for i in range(N)]
        K = max((len(r) for r in rows), default=0) or 1
        idx = np.zeros((N, K), np.int32)
        msk = np.zeros((N, K), np.float32)
        for i, r in enumerate(rows):
            idx[i, : len(r)] = r
            msk[i, : len(r)] = 1.0
        return cls(idx=jnp.asarray(idx), nmask=jnp.asarray(msk))

    def _weights(self, alive: jax.Array | None) -> jax.Array:
        if alive is None:
            return self.nmask
        return self.nmask * alive[self.idx].astype(self.nmask.dtype)

    def degrees(self, alive: jax.Array | None = None) -> jax.Array:
        """(N,) live degree — dead neighbors (churn) drop out."""
        return jnp.sum(self._weights(alive), axis=1)

    def nbr_sum(self, x: jax.Array,
                alive: jax.Array | None = None) -> jax.Array:
        """sum_{n in N(i)} x_n for agent-stacked x (N, ...) — the gossip
        spelling of `adjacency @ x`, restricted to live neighbors."""
        g = x[self.idx]                       # (N, K, ...)
        w = self._weights(alive)
        return jnp.einsum("nk,nk...->n...", w, g)


# ---------------------------------------------------------------------------
# ChurnSchedule (host description) -> GossipPlan (traced scan data)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnSchedule:
    """Scenario knobs for population dynamics, scripted per iteration.

    leave / join   — ((iteration, agent), ...) events, 1-based iterations;
                     effective AT the named iteration. An agent may leave
                     and later rejoin (it restarts from zero state).
    slowdown       — ((agent, factor), ...) straggler factors >= 1: agent
                     i's participation probability is rate / factor (a
                     2x-slow straggler joins half as often).
    start_absent   — agents dead at iteration 1 (they join later).
    """

    leave: tuple = ()
    join: tuple = ()
    slowdown: tuple = ()
    start_absent: tuple = ()

    @property
    def has_events(self) -> bool:
        return bool(self.leave or self.join or self.start_absent)

    def plan(self, num_agents: int, participation: float = 1.0,
             size: int | None = None) -> "GossipPlan":
        """Compile the schedule into the traced arrays the gossip step
        consumes: an event-indexed alive stack plus the straggler vector."""
        def _check_agent(a):
            a = int(a)
            if not 0 <= a < num_agents:
                raise ValueError(
                    f"churn names agent {a} but the problem has "
                    f"{num_agents} agents")
            return a

        if size is not None and not 1 <= size <= num_agents:
            raise ValueError(
                f"gossip_size={size} out of range for {num_agents} agents")

        events: list[tuple[int, int, bool]] = []
        for it, a in self.leave:
            if int(it) < 1:
                raise ValueError(f"churn iterations are 1-based, got {it}")
            events.append((int(it), _check_agent(a), False))
        for it, a in self.join:
            if int(it) < 1:
                raise ValueError(f"churn iterations are 1-based, got {it}")
            events.append((int(it), _check_agent(a), True))
        seen = set()
        for it, a, _ in events:
            if (it, a) in seen:
                raise ValueError(
                    f"conflicting churn events for agent {a} at "
                    f"iteration {it}")
            seen.add((it, a))

        alive = np.ones((num_agents,), bool)
        for a in self.start_absent:
            alive[_check_agent(a)] = False

        event_iters, stack = [], [alive.copy()]
        for it in sorted({e[0] for e in events}):
            for eit, a, up in events:
                if eit == it:
                    alive[a] = up
            event_iters.append(it)
            stack.append(alive.copy())

        slow = None
        if self.slowdown:
            slow = np.ones((num_agents,), np.float32)
            for a, f in self.slowdown:
                if float(f) < 1.0:
                    raise ValueError(
                        f"straggler factors are >= 1 (a slowdown), got {f}")
                slow[_check_agent(a)] = float(f)

        return GossipPlan(
            participation=jnp.asarray(participation, jnp.float32),
            size=size,
            slowdown=None if slow is None else jnp.asarray(slow),
            event_iters=(jnp.asarray(event_iters, jnp.int32)
                         if event_iters else None),
            alive_stack=(jnp.asarray(np.stack(stack))
                         if self.has_events else None))


@partial(jax.tree_util.register_dataclass,
         data_fields=("participation", "slowdown", "event_iters",
                      "alive_stack"),
         meta_fields=("size",))
@dataclasses.dataclass(frozen=True)
class GossipPlan:
    """The traced execution plan of one gossip run. All liveness /
    participation quantities are pytree data, so churn scenarios and
    participation sweeps share one compiled scan."""

    participation: jax.Array          # scalar f32 Bernoulli rate
    size: int | None = None           # fixed-size sampling (overrides rate)
    slowdown: jax.Array | None = None  # (N,) straggler factors >= 1
    event_iters: jax.Array | None = None  # (E,) sorted 1-based iterations
    alive_stack: jax.Array | None = None  # (E + 1, N) bool

    @property
    def has_churn(self) -> bool:
        return self.alive_stack is not None

    def alive_at(self, k) -> jax.Array | None:
        """(N,) liveness during (possibly traced) iteration k; None when
        the run has no churn events (everyone lives)."""
        if self.alive_stack is None:
            return None
        i = jnp.sum((self.event_iters <= k).astype(jnp.int32))
        return self.alive_stack[i]


# ---------------------------------------------------------------------------
# One gossip iteration — the ADMM family (DKLA / COKE)
# ---------------------------------------------------------------------------

def gossip_coke_step(
    problem: Problem,
    policy,
    state: COKEState,
    table: NeighborTable,
    plan: GossipPlan,
    chol: jax.Array | None = None,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
    primal: str = "cg",
    cg_tol: float = 1e-8,
    cg_maxiter: int = 64,
) -> COKEState:
    """One asynchronous iteration of Algorithm 1/2: the sampled
    participants run the (21a) primal + policy-governed broadcast +
    delayed (21b) dual; everyone else holds state and pays zero bits.

    Reads the graph ONLY through `table` — `problem.adjacency` is never
    consumed, so the traced step touches no (N, N) value (the scaling
    contract, pinned by jaxpr inspection)."""
    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(policy), rho=problem.rho,
        exchange=lambda s, k: step_mod.table_view(table, plan, k),
        primal=_primal_stage(problem, primal, chol=chol,
                             inner_steps=inner_steps, inner_lr=inner_lr,
                             cg_tol=cg_tol, cg_maxiter=cg_maxiter),
        comm_decide=step_mod.sampled_stage(plan))
    new_state, _ = step_mod.run_step(program, state)
    return new_state


# ---------------------------------------------------------------------------
# One gossip round — the streaming family (online DKLA/COKE, QC-ODKLA)
# ---------------------------------------------------------------------------

def gossip_stream_step(
    state: OnlineState,
    feats: jax.Array,
    labels: jax.Array,
    table: NeighborTable,
    schedule,
    plan: GossipPlan,
    *,
    lam: float,
    rho: float,
    lr: float,
    eta: float | None = None,
) -> tuple[OnlineState, jax.Array]:
    """The asynchronous `core.online.stream_step`: the round's sampled
    participants take the streaming augmented-Lagrangian step on their
    fresh minibatch and gossip; sleepers hold. Returns (state, pre-update
    instantaneous MSE over the full stack — the stream keeps flowing
    whether or not an agent woke up to learn from it)."""
    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(schedule), rho=rho,
        exchange=lambda s, k: step_mod.table_view(table, plan, k),
        primal=step_mod.stream_primal(feats, labels, lam=lam, rho=rho,
                                      lr=lr, eta=eta),
        comm_decide=step_mod.sampled_stage(plan))
    new_state, extras = step_mod.run_step(program, state)
    return new_state, extras["inst_mse"]


# ---------------------------------------------------------------------------
# ensure_state-style grow/shrink of agent-stacked state
# ---------------------------------------------------------------------------

def grow_agents(tree, old_n: int, new_n: int):
    """Pad every agent-stacked leaf (leading axis == old_n) with zero rows
    up to new_n agents; other leaves (scalars, PRNG keys) pass through.
    The capacity-extension half of churn: existing agents' rows are
    untouched bit-for-bit, new rows start cold (exactly how a joiner
    initializes)."""
    if new_n < old_n:
        raise ValueError(f"grow_agents: {new_n} < current {old_n} "
                         "(use take_agents to shrink)")

    def pad(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == old_n:
            z = jnp.zeros((new_n - old_n, *x.shape[1:]), x.dtype)
            return jnp.concatenate([x, z], axis=0)
        return x

    return jax.tree.map(pad, tree)


def take_agents(tree, old_n: int, index):
    """Select (shrink / reorder) the agent rows of every agent-stacked
    leaf (leading axis == old_n); other leaves pass through. Surviving
    rows are bit-identical — the shrink half of churn."""
    idx = jnp.asarray(index, jnp.int32)

    def take(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == old_n:
            return jnp.take(x, idx, axis=0)
        return x

    return jax.tree.map(take, tree)
