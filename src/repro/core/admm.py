"""DKLA (Algorithm 1) and COKE (Algorithm 2): decentralized kernel learning
via ADMM in the RF space.

This module is the *simulator* form: all N agents live in one process as a
leading batch axis, neighbor exchange is an adjacency matmul, and the whole
iteration runs under `lax.scan`. It is bit-faithful to the paper's update
equations and is the reference the distributed (`repro.distributed.consensus`)
implementation is tested against.

Primal update (18a)/(21a) for the kernel ridge regression loss has a closed
form. With R_hat_i(theta) = (1/T_i)||y_i - Phi_i' theta||^2 + (lam/N)||theta||^2
the stationarity condition of (21a) is

  [ (2/T_i) Phi_i Phi_i' + (2 lam/N + 2 rho |N_i|) I ] theta
        = (2/T_i) Phi_i y_i - gamma_i + rho * sum_n (theta_hat_i + theta_hat_n)

so each agent prefactors its local (D x D) system once (Cholesky) and solves
per iteration. For non-quadratic losses a few gradient steps approximate the
argmin (inexact ADMM) — `inner_steps` controls this.

Primal modes — the big-D axis. The Cholesky primal materializes a dense
per-agent (D, D) factor: O(N D^2) memory and O(D^3) setup, which caps the
RF dimension at a few thousand. The "cg" primal solves the same (21a)
normal equations matrix-free with a Jacobi-preconditioned conjugate
gradient whose only operator application is phi.T @ (phi @ v) — O(N Ti D)
memory, no (D, D) array ever built — and warm-starts from the previous
iterate, so a handful of CG steps per ADMM iteration suffice in practice
(Richards et al. show gradient-based decentralized RF learning is exactly
the large-D regime's method of choice). `resolve_primal` picks the
crossover: Cholesky up to CG_CROSSOVER_DIM features, CG above.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core import losses as losses_mod
from repro.core import step as step_mod
from repro.core.censor import CensorSchedule
from repro.core.graph import Graph, TopologySchedule


class COKEState(NamedTuple):
    """Per-agent state, batched over the leading N axis."""

    theta: jax.Array      # (N, D) local primal variables theta_i^k
    theta_hat: jax.Array  # (N, D) latest *broadcast* primal variables
    gamma: jax.Array      # (N, D) local dual variables
    step: jax.Array       # scalar iteration counter k
    comms: jax.Array      # scalar cumulative number of transmissions
    # policy state (per-agent bits, PRNG key). None — NOT an eager
    # CommState — as the class default: a device-array default would be
    # allocated at module import (before any jax.config/platform choice)
    # and shared across every state. `init_state` builds it lazily and
    # `coke_step`'s ensure_state fills it for legacy eager callers.
    comm: comm_mod.CommState | None = None


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("feats", "labels", "adjacency"),
    meta_fields=("lam", "rho", "loss"),
)
@dataclasses.dataclass(frozen=True)
class Problem:
    """The decentralized RF-space learning problem instance (a pytree:
    array leaves feats/labels/adjacency, static lam/rho/loss)."""

    feats: jax.Array   # (N, T_i, D) RF-mapped local data (equal shards)
    labels: jax.Array  # (N, T_i)
    adjacency: jax.Array  # (N, N)
    lam: float         # global ridge lambda (split lam/N per agent)
    rho: float         # ADMM penalty / step size
    loss: str = "quadratic"

    @property
    def num_agents(self) -> int:
        return self.feats.shape[0]

    @property
    def feature_dim(self) -> int:
        return self.feats.shape[-1]

    @property
    def degrees(self) -> jax.Array:
        return jnp.sum(self.adjacency, axis=1)


def make_problem(
    feats: jax.Array,
    labels: jax.Array,
    graph: Graph,
    lam: float,
    rho: float,
    loss: str = "quadratic",
) -> Problem:
    return Problem(
        feats=feats,
        labels=labels,
        adjacency=jnp.asarray(graph.adjacency, feats.dtype),
        lam=lam,
        rho=rho,
        loss=loss,
    )


def init_state(problem: Problem, policy=None) -> COKEState:
    """theta^0 = theta_hat^0 = gamma^0 = 0 (Algorithms 1/2).

    policy — the communication policy whose persistent state rides in the
    returned COKEState (None = empty chain; `coke_step` re-initializes a
    mismatched structure for eager legacy callers).
    """
    N, D = problem.num_agents, problem.feature_dim
    z = jnp.zeros((N, D), problem.feats.dtype)
    return COKEState(z, z, z, jnp.zeros((), jnp.int32),
                     jnp.zeros((), jnp.int32),
                     comm_mod.as_chain(policy).init_state(N))


# --------------------------------------------------------------------------
# Primal update
# --------------------------------------------------------------------------

#: "auto" switches from the prefactored Cholesky primal to the matrix-free
#: CG primal above this feature dimension. The crossover is a memory cliff,
#: not a flop tie-break: at D = 2048 the per-agent factor is 16 MB (f32) and
#: the O(D^3) factorization still amortizes over a long fit, while at
#: D = 4096 a 20-agent problem already wants 1.3 GB of factors alone —
#: whereas CG's working set stays O(Ti D) per agent at any D.
CG_CROSSOVER_DIM = 2048

PRIMAL_MODES = ("auto", "cholesky", "cg", "gradient")


def resolve_primal(primal: str, feature_dim: int, loss: str) -> str:
    """Resolve a FitConfig primal mode to the concrete update that runs.

    auto     -> "cholesky" up to CG_CROSSOVER_DIM features (exact solve,
                amortized O(D^3) setup), "cg" above (matrix-free); general
                losses have no normal equations and fall back to "gradient".
    cholesky / cg -> forced; both solve (21a) and require the quadratic
                loss (ValueError otherwise — silently running a different
                update would be worse than failing).
    gradient -> the inexact inner-GD primal (any loss; what the SPMD
                runtime's one-step update approximates).
    """
    if primal not in PRIMAL_MODES:
        raise ValueError(
            f"unknown primal mode {primal!r}; choose from {PRIMAL_MODES}")
    if loss != "quadratic":
        if primal in ("cholesky", "cg"):
            raise ValueError(
                f"primal={primal!r} solves the quadratic-loss (21a) normal "
                f"equations; loss={loss!r} has none — use primal='gradient'")
        return "gradient"
    if primal == "auto":
        return "cg" if feature_dim > CG_CROSSOVER_DIM else "cholesky"
    return primal


def _ridge_factors(problem: Problem, deg=None):
    """Per-agent Cholesky factors of the (18a) normal matrix (quadratic
    loss). deg overrides problem.degrees (e.g. a NeighborTable's live
    degrees in gossip execution — same values, no dense adjacency read)."""
    N, Ti, D = problem.feats.shape
    if deg is None:
        deg = problem.degrees

    def factor(phi, d_i):
        A = (2.0 / Ti) * phi.T @ phi
        diag = 2.0 * problem.lam / N + 2.0 * problem.rho * d_i
        A = A + diag * jnp.eye(D, dtype=phi.dtype)
        return jnp.linalg.cholesky(A)

    return jax.vmap(factor)(problem.feats, deg)


def _primal_closed_form(problem: Problem, chol, gamma, theta_ref, nbr_sum,
                        deg=None):
    """Solve (21a) exactly per agent via the prefactored Cholesky system.

    theta_ref / nbr_sum: the (theta_hat_i, sum_n theta_hat_n) pair; DKLA
    passes (theta_i, sum_n theta_n). deg overrides problem.degrees for
    time-varying topologies (the chol factors must match).
    """
    N, Ti, D = problem.feats.shape
    if deg is None:
        deg = problem.degrees

    def solve(phi, y, L, g, t_ref, nb, d_i):
        rhs = (2.0 / Ti) * phi.T @ y - g + problem.rho * (d_i * t_ref + nb)
        z = jax.scipy.linalg.solve_triangular(L, rhs, lower=True)
        return jax.scipy.linalg.solve_triangular(L.T, z, lower=False)

    return jax.vmap(solve)(problem.feats, problem.labels, chol, gamma,
                           theta_ref, nbr_sum, deg)


def _primal_cg(problem: Problem, gamma, theta_ref, nbr_sum, deg=None,
               theta0=None, tol: float = 1e-8, maxiter: int = 64):
    """Solve (21a) per agent matrix-free: Jacobi-preconditioned CG on

        [ (2/Ti) Phi_i Phi_i' + (2 lam/N + 2 rho |N_i|) I ] theta = rhs_i

    applying only phi.T @ (phi @ v) — never a (D, D) matrix. The Jacobi
    diagonal is (2/Ti) sum_t phi[t, d]^2 + diag_reg, an O(Ti D) reduction.
    theta0 warm-starts from the previous ADMM iterate: consecutive primal
    problems differ only through the O(rho) dual/neighbor drift, so a few
    CG steps per iteration recover the closed-form solve to float32
    accuracy (parity pinned against Cholesky in tests/test_big_d.py).
    """
    N, Ti, D = problem.feats.shape
    if deg is None:
        deg = problem.degrees
    if theta0 is None:
        theta0 = jnp.zeros((N, D), problem.feats.dtype)

    def solve(phi, y, g, t_ref, nb, d_i, t0):
        diag_reg = 2.0 * problem.lam / N + 2.0 * problem.rho * d_i
        rhs = (2.0 / Ti) * phi.T @ y - g + problem.rho * (d_i * t_ref + nb)
        jacobi = (2.0 / Ti) * jnp.sum(phi * phi, axis=0) + diag_reg

        def matvec(v):
            return (2.0 / Ti) * (phi.T @ (phi @ v)) + diag_reg * v

        x, _ = jax.scipy.sparse.linalg.cg(
            matvec, rhs, x0=t0, tol=tol, maxiter=maxiter,
            M=lambda v: v / jacobi)
        return x

    return jax.vmap(solve)(problem.feats, problem.labels, gamma,
                           theta_ref, nbr_sum, deg, theta0)


def _primal_gradient(problem: Problem, inner_steps: int, inner_lr: float,
                     theta0, gamma, theta_ref, nbr_sum, deg=None):
    """Inexact (21a) for general convex losses: `inner_steps` GD steps on the
    augmented local objective."""
    N = problem.num_agents
    if deg is None:
        deg = problem.degrees

    def aug(theta_i, phi, y, g, t_ref, nb, d_i):
        r = losses_mod.local_empirical_risk(theta_i, phi, y,
                                            problem.lam / N, problem.loss)
        return (r + problem.rho * d_i * jnp.sum(theta_i * theta_i)
                + jnp.dot(theta_i, g - problem.rho * (d_i * t_ref + nb)))

    grad = jax.vmap(jax.grad(aug), in_axes=(0, 0, 0, 0, 0, 0, 0))

    def body(theta, _):
        g = grad(theta, problem.feats, problem.labels, gamma,
                 theta_ref, nbr_sum, deg)
        return theta - inner_lr * g, None

    theta, _ = jax.lax.scan(body, theta0, None, length=inner_steps)
    return theta


def _primal_stage(problem: Problem, primal: str, *, chol=None,
                  inner_steps: int = 50, inner_lr: float = 0.1,
                  cg_tol: float = 1e-8, cg_maxiter: int = 64,
                  legacy_auto: bool = False):
    """The (21a) primal update as a `core.step` stage, shared by the
    synchronous, gossip, and personalized assemblies. With
    `legacy_auto=True` the dispatch keeps `coke_step`'s historical
    contract (closed form whenever a factor is in hand and the loss is
    quadratic); otherwise the mode is explicit ("cg" / "cholesky" /
    gradient). A per-iteration factor resolved by the exchange stage
    (`GraphView.chol`, the topology-schedule path) overrides the static
    one."""
    def stage(k, g, theta0, theta_hat0, gamma0, nbr_hat):
        c = chol if g.chol is None else g.chol
        if primal == "cg":
            if problem.loss != "quadratic":
                raise ValueError(
                    "primal='cg' solves the quadratic-loss normal "
                    f"equations; loss={problem.loss!r} needs "
                    "primal='gradient'")
            theta = _primal_cg(problem, gamma0, theta_hat0, nbr_hat,
                               g.deg, theta0=theta0, tol=cg_tol,
                               maxiter=cg_maxiter)
        elif (problem.loss == "quadratic" and c is not None
              if legacy_auto else primal == "cholesky"):
            if c is None:
                raise ValueError("primal='cholesky' needs the factor stack")
            theta = _primal_closed_form(problem, c, gamma0, theta_hat0,
                                        nbr_hat, g.deg)
        else:
            theta = _primal_gradient(problem, inner_steps, inner_lr,
                                     theta0, gamma0, theta_hat0, nbr_hat,
                                     g.deg)
        return theta, {}
    return stage


# --------------------------------------------------------------------------
# One COKE / DKLA iteration
# --------------------------------------------------------------------------

def coke_step(
    problem: Problem,
    policy,
    state: COKEState,
    chol: jax.Array | None = None,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
    topology: TopologySchedule | None = None,
    primal: str = "auto",
    cg_tol: float = 1e-8,
    cg_maxiter: int = 64,
) -> COKEState:
    """One iteration of Algorithm 2 for every agent.

    policy — a `core.comm` policy (Chain / stage / CensorSchedule / None):
    the broadcast step is `policy.apply(theta, theta_hat_prev, k)`, which
    covers the paper's censoring (Censor), QC-ODKLA-style quantization
    (Quantize) and unreliable links (Drop). A CensorSchedule with v == 0
    (or an empty Chain) is exactly Algorithm 1 (DKLA).

    topology — optional time-varying graph schedule; iteration k runs on
    `topology.at(k)`. With the closed-form primal, pass the per-graph
    Cholesky stack (M, N, D, D) as `chol` and the step selects the factor
    matching the active graph.

    primal — "auto" keeps the legacy contract (closed form when `chol` is
    given and the loss is quadratic, the inexact gradient argmin
    otherwise); "cg" runs the matrix-free Jacobi-CG solve of (21a)
    (no `chol` needed — nothing (D, D) is ever built), warm-started from
    the previous iterate with `cg_tol`/`cg_maxiter` as stops.
    """
    if topology is None:
        def exchange(s, k):
            return step_mod.dense_view(problem.adjacency,
                                       deg=problem.degrees)
    else:
        def exchange(s, k):
            c = chol
            if c is not None and c.ndim == 4:
                c = c[topology.index(k)]
            return step_mod.dense_view(topology.at(k), chol=c)

    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(policy), rho=problem.rho,
        exchange=exchange,
        primal=_primal_stage(problem, primal, chol=chol,
                             inner_steps=inner_steps, inner_lr=inner_lr,
                             cg_tol=cg_tol, cg_maxiter=cg_maxiter,
                             legacy_auto=True))
    new_state, _ = step_mod.run_step(program, state)
    return new_state


class RunResult(NamedTuple):
    state: COKEState
    train_mse: jax.Array   # (K,) global training MSE per iteration
    comms: jax.Array       # (K,) cumulative transmissions per iteration
    consensus_gap: jax.Array  # (K,) max_i ||theta_i - mean(theta)||


@partial(jax.jit, static_argnames=("num_iters", "schedule", "inner_steps"))
def _run(
    problem: Problem,
    schedule: CensorSchedule,
    num_iters: int,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
) -> RunResult:
    """Run COKE (or DKLA when schedule.v == 0) for `num_iters` iterations,
    recording the paper's evaluation metrics (MSE(k), cumulative comms)."""
    chol = _ridge_factors(problem) if problem.loss == "quadratic" else None
    state0 = init_state(problem, policy=schedule)

    def metrics(state: COKEState):
        preds = jnp.einsum("ntd,nd->nt", problem.feats, state.theta)
        mse = jnp.mean((problem.labels - preds) ** 2)
        mean_theta = jnp.mean(state.theta, axis=0, keepdims=True)
        gap = jnp.max(
            jnp.sqrt(jnp.sum((state.theta - mean_theta) ** 2, axis=-1)))
        return mse, gap

    def body(state, _):
        state = coke_step(problem, schedule, state, chol,
                          inner_steps, inner_lr)
        mse, gap = metrics(state)
        return state, (mse, state.comms, gap)

    state, (mse, comms, gap) = jax.lax.scan(body, state0, None,
                                            length=num_iters)
    return RunResult(state, mse, comms, gap)


def run(
    problem: Problem,
    schedule: CensorSchedule,
    num_iters: int,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
) -> RunResult:
    """Deprecated entry point — use `repro.api.fit(FitConfig(...))`.

    Note this shim retraces per distinct `schedule` (it is a static jit
    argument); `repro.api.fit` traces the thresholds so censor sweeps share
    one compiled loop.
    """
    warnings.warn(
        "repro.core.admm.run is deprecated; use repro.api.fit("
        "FitConfig(algorithm='coke'|'dkla', ...))",
        DeprecationWarning, stacklevel=2)
    return _run(problem, schedule, num_iters, inner_steps, inner_lr)


def dkla_schedule() -> CensorSchedule:
    """The h == 0 schedule under which COKE *is* DKLA."""
    return CensorSchedule(v=0.0, mu=0.5)
