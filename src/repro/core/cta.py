"""CTA — the diffusion-based combine-then-adapt baseline (Section 5).

The paper's comparison baseline: at each iteration every agent (a) combines
neighbor parameters with doubly-stochastic Metropolis weights, then (b) takes
a gradient-descent step on its local RF-space cost (15). It communicates at
every iteration (no censoring), so its communication cost is N per step.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import losses as losses_mod
from repro.core.admm import Problem
from repro.core.graph import Graph, metropolis_weights


class CTAState(NamedTuple):
    theta: jax.Array  # (N, D)
    step: jax.Array
    comms: jax.Array


class CTAResult(NamedTuple):
    state: CTAState
    train_mse: jax.Array
    comms: jax.Array


def init_state(problem: Problem) -> CTAState:
    N, D = problem.num_agents, problem.feature_dim
    return CTAState(jnp.zeros((N, D), problem.feats.dtype),
                    jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def cta_step(problem: Problem, mixing: jax.Array, lr: float,
             state: CTAState) -> CTAState:
    # combine ...
    combined = mixing @ state.theta
    # ... then adapt
    N = problem.num_agents

    def local_grad(theta_i, phi, y):
        return jax.grad(losses_mod.local_empirical_risk)(
            theta_i, phi, y, problem.lam / N, problem.loss)

    g = jax.vmap(local_grad)(combined, problem.feats, problem.labels)
    theta = combined - lr * g
    return CTAState(theta, state.step + 1,
                    state.comms + jnp.asarray(N, jnp.int32))


@partial(jax.jit, static_argnames=("num_iters",))
def _run(problem: Problem, mixing: jax.Array, lr: float,
         num_iters: int) -> CTAResult:
    def body(state, _):
        state = cta_step(problem, mixing, lr, state)
        preds = jnp.einsum("ntd,nd->nt", problem.feats, state.theta)
        mse = jnp.mean((problem.labels - preds) ** 2)
        return state, (mse, state.comms)

    state, (mse, comms) = jax.lax.scan(body, init_state(problem), None,
                                       length=num_iters)
    return CTAResult(state, mse, comms)


def run(problem: Problem, graph: Graph, lr: float,
        num_iters: int) -> CTAResult:
    """Deprecated entry point — use
    `repro.api.fit(FitConfig(algorithm='cta', ...))`."""
    warnings.warn(
        "repro.core.cta.run is deprecated; use repro.api.fit("
        "FitConfig(algorithm='cta', ...))",
        DeprecationWarning, stacklevel=2)
    mixing = jnp.asarray(metropolis_weights(graph), problem.feats.dtype)
    return _run(problem, mixing, lr, num_iters)
