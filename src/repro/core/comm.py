"""Composable communication policies — the communication *rule* as a
first-class axis of the API, decoupled from the solvers.

COKE's contribution is a rule about *when* to transmit (censoring); QC-ODKLA
(Xu et al., 2022) shows it composes with *what* to transmit (quantized
innovations); unreliable networks add *whether the link carries it* (drops).
This module expresses all three as stages of one pipeline over a broadcast
message:

    policy = Chain([Censor(v=0.5, mu=0.97),   # Eq. 19-20: h(k) = v mu^k
                    Quantize(bits=4),         # stochastic b-bit innovations
                    Drop(p=0.05)])            # Bernoulli link failures

Each stage implements the protocol

    init_state(num_agents)          -> persistent per-stage pytree state
    transform(msg, state, k, key)   -> (msg, state)

and a `Chain` runs the message through every stage, finalizes the masked
broadcast (stale-value fallback), and accounts the **bits** each transmitter
paid — the cost metric the accuracy-vs-bits tradeoff curves are drawn in.
All numeric stage parameters (v, mu, bits, p) are pytree *data*, so policy
grids trace through one compiled fit loop and `sweep()` can vmap over
stacked policies.

Randomness contract: the chain's stochastic stages (Quantize rounding, Drop
link loss) draw from a PRNG key carried in `CommState` as pytree *data*.
`Chain.init_state` derives that key by folding the static stage seeds AND
every numeric policy parameter (bit-cast to uint32) into a base key, then
`Chain.apply` folds in the iteration k and the stage index. Consequences:
  * two sweep cells with different parameters draw INDEPENDENT noise (under
    `sweep()`'s vmap the folded parameters are per-cell traced values), so
    `select()` never compares cells through perfectly correlated noise;
  * two cells with identical parameters stay bit-identical (the
    deterministic tie-break contract of `SweepResult.select`);
  * replays are deterministic in (policy, seed, k), and the simulator /
    spmd / fused backends derive identical draws from identical state.

Semantics (bulk-synchronous value-masking, see DESIGN.md §3):
  * `send` is the transmitter's decision — a censored agent pays nothing;
  * `delivered` models the network — a dropped broadcast was *paid for* by
    the transmitter but receivers keep the stale value (per-broadcast drops:
    the agent's whole round is lost, matching the one-theta_hat-per-agent
    state both the simulator and the ring runtime carry);
  * receivers adopt `payload` (possibly quantized) iff send AND delivered.

With `Chain([Censor(v, mu), Quantize(bits=inf), Drop(p=0)])` every stage is
exactly the identity extension of the paper's rule, and trajectories are
bit-identical to COKE (pinned in tests/test_comm.py and tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.censor import (CensorSchedule, censor_decision,
                               masked_broadcast)

#: uncompressed payload precision: float32 coordinates
FP_BITS = 32.0


class Msg(NamedTuple):
    """One broadcast round in flight through the policy pipeline."""

    payload: jax.Array         # (N, D) values receivers adopt if delivered
    prev: jax.Array            # (N, D) stale broadcast the receivers hold
    send: jax.Array            # (N,) bool: transmitter decisions (paid)
    delivered: jax.Array       # (N,) bool: links that carried the message
    bits_per_value: jax.Array  # scalar f32: per-coordinate payload width
    overhead_bits: jax.Array   # scalar f32: per-message header (e.g. scale)


class CommState(NamedTuple):
    """Persistent policy state threaded through the fit loop's scan.

    bits is float32, not int32: a 100M-param broadcast is 3.2e9 bits — one
    step would overflow int32, while f32 stays exact through 2^24 and keeps
    ~1e-7 relative accuracy at deep-net scales (and both backends compute
    it identically, so cross-backend equality tests remain exact).

    key is the chain-level PRNG key the stochastic stages draw from. It is
    pytree DATA (not a static seed), derived in `Chain.init_state` from the
    stage seeds and the numeric policy parameters — under `sweep()`'s vmap
    each grid cell therefore carries its own independent stream instead of
    every cell replaying one module-level seed."""

    bits: jax.Array     # (N,) float32 cumulative bits paid by each agent
    key: jax.Array      # chain-level PRNG key (uint32 key data)
    stages: tuple = ()  # per-stage persistent states (matches Chain.stages)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("v", "mu"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class Censor:
    """The CO in COKE: transmit iff ||payload - prev|| >= v * mu^k."""

    v: float = 1.0
    mu: float = 0.95

    def init_state(self, num_agents: int):
        return ()

    def transform(self, msg: Msg, state, k, key=None) -> tuple[Msg, tuple]:
        h_k = (jnp.asarray(self.v) * jnp.asarray(self.mu) ** k).astype(
            msg.payload.dtype)
        send = censor_decision(msg.payload, msg.prev, h_k)
        return msg._replace(send=msg.send & send), state


@partial(jax.tree_util.register_dataclass,
         data_fields=("bits",), meta_fields=("seed", "stochastic"))
@dataclasses.dataclass(frozen=True)
class Quantize:
    """The Q in QC-ODKLA: b-bit uniform quantization of the *innovation*
    (payload - prev), stochastically rounded (unbiased), with a per-agent
    float32 scale shipped as message overhead. bits=inf is the exact
    identity (full-precision payload, FP_BITS accounting)."""

    bits: float = 8.0
    seed: int = 0
    stochastic: bool = True

    def init_state(self, num_agents: int):
        return ()

    def transform(self, msg: Msg, state, k, key=None) -> tuple[Msg, tuple]:
        b = jnp.asarray(self.bits, jnp.float32)
        innov = msg.payload - msg.prev
        levels = 2.0 ** (b - 1.0) - 1.0           # signed symmetric range
        scale = jnp.max(jnp.abs(innov), axis=-1, keepdims=True)
        safe = jnp.where(scale > 0, scale, 1.0)
        x = innov / safe * levels                 # in [-levels, levels]
        if self.stochastic:
            if key is None:   # bare-stage calls outside a Chain
                key = jax.random.fold_in(jax.random.PRNGKey(self.seed), k)
            lo = jnp.floor(x)
            x = lo + (jax.random.uniform(key, x.shape) < (x - lo)).astype(
                x.dtype)
        else:
            x = jnp.round(x)
        deq = msg.prev + x / levels * safe
        finite = jnp.isfinite(levels)             # bits=inf -> identity
        return msg._replace(
            payload=jnp.where(finite, deq, msg.payload),
            bits_per_value=jnp.where(finite, b, msg.bits_per_value),
            overhead_bits=msg.overhead_bits + jnp.where(finite, FP_BITS,
                                                        0.0)), state


@partial(jax.tree_util.register_dataclass,
         data_fields=("p",), meta_fields=("seed",))
@dataclasses.dataclass(frozen=True)
class Drop:
    """Bernoulli(p) link failure per broadcast: the transmitter pays, the
    receivers keep the stale value. p=0 is the exact identity."""

    p: float = 0.0
    seed: int = 1

    def init_state(self, num_agents: int):
        return ()

    def transform(self, msg: Msg, state, k, key=None) -> tuple[Msg, tuple]:
        if key is None:       # bare-stage calls outside a Chain
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), k)
        keep = jax.random.uniform(key, msg.delivered.shape) >= jnp.asarray(
            self.p, jnp.float32)
        return msg._replace(delivered=msg.delivered & keep), state


STAGE_TYPES = (Censor, Quantize, Drop)


def _fold_value(key: jax.Array, leaf) -> jax.Array:
    """Fold a numeric policy parameter into a PRNG key, bit-exactly: the
    float32 bit pattern is the fold data, so any parameter change — however
    small — moves the stream, while equal parameters (traced or concrete)
    fold identically."""
    u = jax.lax.bitcast_convert_type(jnp.asarray(leaf, jnp.float32),
                                     jnp.uint32)
    if u.ndim == 0:
        return jax.random.fold_in(key, u)
    for v in jnp.ravel(u):      # static length: policy params are tiny
        key = jax.random.fold_in(key, v)
    return key


# ---------------------------------------------------------------------------
# Chain: the composed policy
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("stages",), meta_fields=())
@dataclasses.dataclass(frozen=True)
class Chain:
    """Ordered composition of stages; Chain(()) is the always-transmit
    full-precision broadcast (DKLA's policy)."""

    stages: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "stages", tuple(self.stages))

    def chain_key(self) -> jax.Array:
        """The chain's base PRNG key: static stage seeds folded with every
        numeric policy parameter. Pytree data — per-cell under sweep vmap."""
        key = jax.random.PRNGKey(0)
        for i, s in enumerate(self.stages):
            key = jax.random.fold_in(key, i)
            seed = getattr(s, "seed", None)
            if seed is not None:
                key = jax.random.fold_in(key, int(seed))
        for leaf in jax.tree.leaves(self):
            key = _fold_value(key, leaf)
        return key

    def init_state(self, num_agents: int) -> CommState:
        return CommState(
            bits=jnp.zeros((num_agents,), jnp.float32),
            key=self.chain_key(),
            stages=tuple(s.init_state(num_agents) for s in self.stages))

    def ensure_state(self, state: CommState | None,
                     num_agents: int) -> CommState:
        """Re-initialize per-stage states when `state` was built for a
        different chain structure or agent count (legacy eager callers);
        preserves the cumulative bits when their shape still fits. A no-op
        for matching structures, so scan carries stay stable."""
        if state is None:
            return self.init_state(num_agents)
        if state.bits.shape != (num_agents,):
            return self.init_state(num_agents)
        if len(state.stages) != len(self.stages):
            return CommState(bits=state.bits, key=self.chain_key(),
                             stages=tuple(
                                 s.init_state(num_agents)
                                 for s in self.stages))
        return state

    def apply(self, theta: jax.Array, prev: jax.Array, k,
              state: CommState,
              active: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array, CommState]:
        """Run one broadcast round: (N, D) candidate values against the
        (N, D) stale copies. Returns (theta_hat, send, new_state).

        active — optional (N,) bool participation mask (gossip execution):
        an inactive agent is structurally silent this round — it cannot
        send regardless of the stage decisions, pays zero bits, and its
        receivers keep the stale value. `active=None` (and an all-true
        mask) is exactly the bulk-synchronous broadcast."""
        num_agents = theta.shape[0]
        dim = theta.shape[-1]
        send0 = (jnp.ones((num_agents,), bool) if active is None
                 else active.astype(bool))
        msg = Msg(payload=theta, prev=prev,
                  send=send0,
                  delivered=jnp.ones((num_agents,), bool),
                  bits_per_value=jnp.asarray(FP_BITS, jnp.float32),
                  overhead_bits=jnp.zeros((), jnp.float32))
        # per-round entropy: the carried key is constant through the scan;
        # folding the (traced) iteration k and the stage index yields a
        # deterministic, replayable stream that differs per round and stage
        round_key = jax.random.fold_in(state.key,
                                       jnp.asarray(k, jnp.uint32))
        sstates = []
        for i, (stage, ss) in enumerate(zip(self.stages, state.stages)):
            msg, ss = stage.transform(msg, ss, k,
                                      key=jax.random.fold_in(round_key, i))
            sstates.append(ss)
        effective = msg.send & msg.delivered
        theta_hat = masked_broadcast(msg.payload, prev, effective)
        per_msg = dim * msg.bits_per_value + msg.overhead_bits
        paid = jnp.where(msg.send, per_msg, 0.0)
        return theta_hat, msg.send, CommState(bits=state.bits + paid,
                                              key=state.key,
                                              stages=tuple(sstates))

    def describe(self) -> str:
        """Human/JSON-friendly one-liner, e.g. 'censor(v=0.5,mu=0.97)|
        quantize(bits=4)|drop(p=0.05)'; 'broadcast' for the empty chain."""
        if not self.stages:
            return "broadcast"
        parts = []
        for s in self.stages:
            if isinstance(s, Censor):
                parts.append(f"censor(v={s.v},mu={s.mu})")
            elif isinstance(s, Quantize):
                parts.append(f"quantize(bits={s.bits})")
            elif isinstance(s, Drop):
                parts.append(f"drop(p={s.p})")
            else:
                parts.append(type(s).__name__.lower())
        return "|".join(parts)


def as_chain(policy) -> Chain:
    """Normalize any policy spelling to a Chain: None -> always-broadcast,
    a CensorSchedule -> the paper's rule, a bare stage -> singleton chain."""
    if policy is None:
        return Chain(())
    if isinstance(policy, Chain):
        return policy
    if isinstance(policy, CensorSchedule):
        return Chain((Censor(policy.v, policy.mu),))
    if isinstance(policy, STAGE_TYPES):
        return Chain((policy,))
    if isinstance(policy, (list, tuple)):
        return Chain(tuple(policy))
    raise TypeError(
        f"not a communication policy: {policy!r} (expected Chain, a stage, "
        "a CensorSchedule, a stage sequence, or None)")


def censored(policy) -> bool:
    """Structural enablement: does the policy contain a Censor stage?
    (Derived from the config, NOT from the float threshold — the thresholds
    are traced and cannot drive Python control flow.)"""
    return any(isinstance(s, Censor) for s in as_chain(policy).stages)


def uncensored(chain: Chain) -> Chain:
    """Same pytree structure with every censor threshold forced to zero —
    the always-transmit (DKLA) variant of a policy. Keeping the structure
    (rather than removing the stage) lets DKLA share compiled loops and
    vmapped sweeps with COKE."""
    return Chain(tuple(
        dataclasses.replace(s, v=s.v * 0) if isinstance(s, Censor) else s
        for s in chain.stages))


# ---------------------------------------------------------------------------
# Agent-stacked pytree adapter (the spmd/fused runtime's message form)
# ---------------------------------------------------------------------------

def flatten_agents(tree) -> tuple[jax.Array, list]:
    """Agent-stacked pytree -> ((N, D_total) float32, leaves)."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    return flat, leaves


def unflatten_agents(flat: jax.Array, leaves: list, treedef=None):
    """Inverse of flatten_agents; returns leaves (or the tree if treedef)."""
    out, off = [], 0
    n = leaves[0].shape[0]
    for leaf in leaves:
        size = leaf.size // n
        out.append(flat[:, off:off + size].reshape(leaf.shape))
        off += size
    if treedef is None:
        return out
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_tree(chain: Chain, params_tree, prev_tree, k,
               state: CommState, active: jax.Array | None = None):
    """Chain.apply over agent-stacked pytrees: flatten both trees to
    (N, D_total) float32, run the policy once over the concatenated
    coordinates (one decision per agent, as in the flat form), unflatten
    the resulting broadcast. Bit-compatible with the flat path when the
    tree has a single (N, D) leaf — the cross-backend parity contract.
    `active` is the gossip participation mask (see Chain.apply)."""
    flat, leaves = flatten_agents(params_tree)
    prev_flat, _ = flatten_agents(prev_tree)
    hat_flat, send, state = chain.apply(flat, prev_flat, k, state,
                                        active=active)
    hat_tree = unflatten_agents(hat_flat, leaves,
                                jax.tree.structure(params_tree))
    return hat_tree, send, state
