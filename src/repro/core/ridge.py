"""Centralized closed-form solvers — the oracles the decentralized algorithms
must converge to (Theorems 1/2 measure distance to these).

* `rf_ridge` implements Eq. (26): theta* = (Phi~'Phi~ + lam I)^{-1} Phi~'y~
  in the RF space (dimension D, cheap).
* `kernel_ridge` implements Eq. (37) in the full RKHS (dimension T) — used
  only in small tests, it carries the curse of dimensionality the paper is
  escaping from.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _stack_scaled(feats_all: jax.Array, labels_all: jax.Array):
    """Build Phi~ in R^{T x D} and y~ in R^T with the 1/sqrt(T_i) row scaling
    of Eq. (26). feats_all: (N, T_i, D), labels_all: (N, T_i) (equal shards)."""
    N, Ti, D = feats_all.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(Ti, feats_all.dtype))
    phi = (feats_all * scale).reshape(N * Ti, D)
    y = (labels_all * scale).reshape(N * Ti)
    return phi, y


def rf_ridge(
    feats_all: jax.Array, labels_all: jax.Array, lam: float
) -> jax.Array:
    """Optimal theta* of the RF-space objective (25)/(26)."""
    phi, y = _stack_scaled(feats_all, labels_all)
    D = phi.shape[1]
    gram = phi.T @ phi + lam * jnp.eye(D, dtype=phi.dtype)
    return jnp.linalg.solve(gram, phi.T @ y)


def kernel_ridge(
    kernel_matrix: jax.Array, labels: jax.Array, lam: float, num_samples_per_agent: int
) -> jax.Array:
    """Optimal alpha* of Eq. (37) with equal shards.

    kernel_matrix: (T, T) Gram over all data; labels: (T,).
    With equal T_i, K~ = K / sqrt(T_i) and y~ = y / sqrt(T_i), so
    alpha* = (K~'K~ + lam K)^{-1} K~' y~ = (K K / T_i + lam K)^{-1} K y / T_i.
    """
    Ti = num_samples_per_agent
    K = kernel_matrix
    T = K.shape[0]
    lhs = K @ K / Ti + lam * K + 1e-8 * jnp.eye(T, dtype=K.dtype)
    rhs = K @ labels / Ti
    return jnp.linalg.solve(lhs, rhs)


def effective_degrees_of_freedom(kernel_matrix: jax.Array, lam: float) -> jax.Array:
    """d_K^lambda = Tr(K (K + lam T I)^{-1}) — Theorem 3's feature-count knob."""
    T = kernel_matrix.shape[0]
    eig = jnp.linalg.eigvalsh(kernel_matrix)
    return jnp.sum(eig / (eig + lam * T))


def sufficient_features(kernel_matrix: jax.Array, lam: float,
                        eps: float = 0.5, delta: float = 0.1) -> float:
    """The L >= (1/lam)(1/eps^2 + 2/(3 eps)) log(16 d_K^lam / delta) bound."""
    d = float(effective_degrees_of_freedom(kernel_matrix, lam))
    import math
    return (1.0 / lam) * (1.0 / eps**2 + 2.0 / (3.0 * eps)) * math.log(16.0 * d / delta)
