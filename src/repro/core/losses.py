"""Loss functions for the decentralized learning objective (Section 2).

All losses are convex in the prediction; in the RF space the composite local
objective R_hat_i(theta) is (strongly, with the ridge term) convex — the
property Remark 1 of the paper highlights as the payoff of RF mapping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quadratic(y: jax.Array, y_hat: jax.Array) -> jax.Array:
    """(y - y_hat)^2 — regression (the paper's analyzed case)."""
    return (y - y_hat) ** 2


def logistic(y: jax.Array, y_hat: jax.Array) -> jax.Array:
    """log(1 + exp(-y * y_hat)) — binary classification, y in {-1, +1}."""
    return jnp.logaddexp(0.0, -y * y_hat)


def hinge(y: jax.Array, y_hat: jax.Array) -> jax.Array:
    """max(0, 1 - y * y_hat) — SVM-style classification."""
    return jnp.maximum(0.0, 1.0 - y * y_hat)


LOSSES = {"quadratic": quadratic, "logistic": logistic, "hinge": hinge}


def local_empirical_risk(
    theta: jax.Array,
    feats: jax.Array,
    labels: jax.Array,
    lam: float,
    loss: str = "quadratic",
) -> jax.Array:
    """R_hat_i(theta) of Eq. (15): mean loss over the local shard + ridge.

    feats: (T_i, D) RF-mapped inputs; labels: (T_i,); lam is lambda_i (the
    per-agent share lambda/N in the common-regularizer convention).
    """
    preds = feats @ theta
    data_term = jnp.mean(LOSSES[loss](labels, preds))
    return data_term + lam * jnp.sum(theta * theta)


def global_empirical_risk(theta, feats_all, labels_all, lam_total, loss="quadratic"):
    """Sum_i R_hat_i(theta) for the centralized benchmark (16).

    feats_all: (N, T, D); labels_all: (N, T). lam_total = lambda (split as
    lambda/N per agent).
    """
    N = feats_all.shape[0]
    per_agent = jax.vmap(
        lambda f, y: local_empirical_risk(theta, f, y, lam_total / N, loss)
    )(feats_all, labels_all)
    return jnp.sum(per_agent)
