"""Core contribution of the paper: RF-space decentralized kernel learning.

Public API:
  rff        — random Fourier feature mapping (common-seed draw, featurize)
  graph      — network topologies + incidence spectra for the rho-condition
  losses     — convex losses + the RF-space local empirical risk (15)
  ridge      — centralized closed-form oracles (26)/(37) + d_K^lambda
  censor     — censoring schedule h(k) = v mu^k and masked broadcast
  admm       — DKLA (Alg. 1) and COKE (Alg. 2) batched simulator
  cta        — diffusion combine-then-adapt baseline
  online     — streaming COKE (beyond-paper: the stated future-work setting)
"""
from repro.core import (admm, censor, cta, graph, losses, online,  # noqa: F401
                        rff, ridge)
