"""Communication censoring — the CO in COKE.

The censoring rule (Eqs. 19-20): agent i transmits theta_i^k iff

    H_i(k, xi) = ||theta_hat_i^{k-1} - theta_i^k||_2 - h_i(k) >= 0,

with h(k) = v * mu^k a non-increasing, non-negative threshold sequence
(Theorem 2 requires exactly this geometric form for linear convergence).

In a bulk-synchronous SPMD program the decision is computed on every replica
and applied by value-masking (see DESIGN.md §3); here we provide the schedule
and the masked-update primitive shared by the simulator and the distributed
runtime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CensorSchedule:
    """h(k) = v * mu^k. v=0 disables censoring (COKE degenerates to DKLA)."""

    v: float = 1.0
    mu: float = 0.95

    def __call__(self, k: jax.Array | int) -> jax.Array:
        return jnp.asarray(self.v) * jnp.asarray(self.mu) ** k

    @property
    def enabled(self) -> bool:
        return self.v > 0.0


def censor_decision(
    theta: jax.Array, theta_hat_prev: jax.Array, threshold: jax.Array
) -> jax.Array:
    """send flag per agent: ||theta_hat_prev - theta||_2 >= h(k).

    theta, theta_hat_prev: (..., D); returns boolean (...,).
    """
    xi = theta_hat_prev - theta
    return jnp.sqrt(jnp.sum(xi * xi, axis=-1)) >= threshold


def masked_broadcast(
    theta: jax.Array, theta_hat_prev: jax.Array, send: jax.Array
) -> jax.Array:
    """theta_hat^k = theta^k where transmitted, else the stale copy."""
    return jnp.where(send[..., None], theta, theta_hat_prev)
