"""Communication censoring — the CO in COKE.

The censoring rule (Eqs. 19-20): agent i transmits theta_i^k iff

    H_i(k, xi) = ||theta_hat_i^{k-1} - theta_i^k||_2 - h_i(k) >= 0,

with h(k) = v * mu^k a non-increasing, non-negative threshold sequence
(Theorem 2 requires exactly this geometric form for linear convergence).

In a bulk-synchronous SPMD program the decision is computed on every replica
and applied by value-masking (see DESIGN.md §3); here we provide the schedule
and the masked-update primitive shared by the simulator and the distributed
runtime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CensorSchedule:
    """h(k) = v * mu^k. v=0 disables censoring (COKE degenerates to DKLA)."""

    v: float = 1.0
    mu: float = 0.95

    def __call__(self, k: jax.Array | int) -> jax.Array:
        return jnp.asarray(self.v) * jnp.asarray(self.mu) ** k

    # NOTE: there is deliberately no `enabled` property here. v is traced
    # through the compiled fit loop, so a static `v > 0` check is at best
    # dead and at worst a silent lie under tracing; enablement is structural
    # — a policy censors iff it contains a Censor stage (core.comm.censored).


def censor_decision(
    theta: jax.Array, theta_hat_prev: jax.Array, threshold: jax.Array
) -> jax.Array:
    """send flag per agent: ||theta_hat_prev - theta||_2 >= h(k).

    theta, theta_hat_prev: (..., D); returns boolean (...,).
    """
    xi = theta_hat_prev - theta
    return jnp.sqrt(jnp.sum(xi * xi, axis=-1)) >= threshold


def masked_broadcast(
    theta: jax.Array, theta_hat_prev: jax.Array, send: jax.Array
) -> jax.Array:
    """theta_hat^k = theta^k where transmitted, else the stale copy.

    theta / theta_hat_prev: (..., D) with matching shape and dtype;
    send: boolean (...,) — one decision per agent, masking the trailing
    feature axis wholesale (an agent transmits its full vector or nothing).
    """
    theta = jnp.asarray(theta)
    theta_hat_prev = jnp.asarray(theta_hat_prev)
    send = jnp.asarray(send)
    if theta.ndim < 1:
        raise ValueError(
            f"masked_broadcast needs a trailing feature axis; got scalar "
            f"theta of shape {theta.shape}")
    if theta.shape != theta_hat_prev.shape:
        raise ValueError(
            f"theta {theta.shape} and theta_hat_prev "
            f"{theta_hat_prev.shape} must match")
    if theta.dtype != theta_hat_prev.dtype:
        raise ValueError(
            f"theta dtype {theta.dtype} != theta_hat_prev dtype "
            f"{theta_hat_prev.dtype}: a silent upcast would desynchronize "
            "the replicas' broadcast values")
    if send.shape != theta.shape[:-1]:
        raise ValueError(
            f"send {send.shape} must be theta's batch shape "
            f"{theta.shape[:-1]} (one decision per agent, not per "
            "coordinate)")
    if send.dtype != jnp.bool_:
        raise ValueError(f"send must be boolean, got {send.dtype}")
    return jnp.where(send[..., None], theta, theta_hat_prev)
