"""Random Fourier feature (RFF) mapping — the enabling transform of the paper.

Implements both real-valued mappings of Rahimi & Recht (2008) referenced by the
paper as Eq. (12) (cos/sin pairs, output dim 2L) and Eq. (13)
(sqrt(2)*cos(w'x + b), output dim L), plus the Gaussian-kernel spectral draw
with a *common seed* across agents (Algorithm 1/2, step 1).

The feature map is the data-independent bridge that turns the T-dimensional
kernel decision variable alpha into the fixed-size theta in R^L on which
consensus is possible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

Mapping = Literal["cos_sin", "cos_bias"]


@dataclasses.dataclass(frozen=True)
class RFFParams:
    """Frozen random-feature parameters shared by every agent.

    omega : (d, L) spectral samples from p_kappa(omega).
    bias  : (L,) uniform [0, 2pi) phases (only used by the 'cos_bias' map).
    mapping : which real-valued mapping to apply.
    """

    omega: jax.Array
    bias: jax.Array
    mapping: Mapping = "cos_bias"

    @property
    def num_features(self) -> int:
        L = self.omega.shape[1]
        return 2 * L if self.mapping == "cos_sin" else L

    @property
    def input_dim(self) -> int:
        return self.omega.shape[0]


def draw_rff(
    key: jax.Array,
    input_dim: int,
    num_features: int,
    bandwidth: float = 1.0,
    mapping: Mapping = "cos_bias",
    dtype=jnp.float32,
) -> RFFParams:
    """Draw L iid spectral samples for a Gaussian kernel of the given bandwidth.

    For kappa(x, x') = exp(-||x - x'||^2 / (2 sigma^2)) the spectral density is
    N(0, sigma^{-2} I) — Bochner's theorem, Eq. (10) of the paper.

    The caller passes the *common random seed*; every agent calling with the
    same key obtains identical features, which is what makes theta comparable
    across agents without any raw-data exchange.
    """
    k_omega, k_bias = jax.random.split(key)
    L = num_features // 2 if mapping == "cos_sin" else num_features
    omega = jax.random.normal(k_omega, (input_dim, L), dtype) / bandwidth
    bias = jax.random.uniform(k_bias, (L,), dtype, 0.0, 2.0 * jnp.pi)
    return RFFParams(omega=omega, bias=bias, mapping=mapping)


def featurize(params: RFFParams, x: jax.Array) -> jax.Array:
    """phi_L(x): map raw inputs (..., d) to RF-space features (..., D).

    D = L for 'cos_bias' (Eq. 13), D = 2L for 'cos_sin' (Eq. 12). Both are
    scaled so that E[phi(x)'phi(x')] = kappa(x, x') and ||phi(x)||_2 <= 1,
    the bound used in the convergence proof (Eq. 33).
    """
    proj = x @ params.omega  # (..., L)
    L = params.omega.shape[1]
    if params.mapping == "cos_sin":
        feats = jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1)
        return feats * jnp.sqrt(1.0 / L).astype(feats.dtype)
    feats = jnp.sqrt(2.0).astype(proj.dtype) * jnp.cos(proj + params.bias)
    return feats * jnp.sqrt(1.0 / L).astype(feats.dtype)


def approx_kernel(params: RFFParams, x: jax.Array, y: jax.Array) -> jax.Array:
    """kappa_hat_L(x, y) = phi_L(x)' phi_L(y) — Eq. (11)."""
    return featurize(params, x) @ featurize(params, y).T


def exact_gaussian_kernel(x: jax.Array, y: jax.Array, bandwidth: float) -> jax.Array:
    """Exact Gaussian Gram matrix — oracle for RFF approximation tests."""
    sq = (
        jnp.sum(x * x, -1)[:, None]
        - 2.0 * x @ y.T
        + jnp.sum(y * y, -1)[None, :]
    )
    return jnp.exp(-sq / (2.0 * bandwidth**2))


@functools.partial(jax.jit, static_argnames=("mapping",))
def _featurize_jit(omega, bias, x, mapping: Mapping):
    return featurize(RFFParams(omega, bias, mapping), x)


def featurize_jit(params: RFFParams, x: jax.Array) -> jax.Array:
    """jit'd convenience entry point used by the data pipeline."""
    return _featurize_jit(params.omega, params.bias, x, params.mapping)
