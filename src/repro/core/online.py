"""Online (streaming) COKE — the paper's stated future-work direction
("future work will be devoted to decentralized online kernel learning").

Each iteration every agent receives a FRESH minibatch from its local
stream, takes a gradient step on the streaming augmented Lagrangian (the
batch Cholesky solve no longer applies — data changes every round), censors
its broadcast with the same h(k) = v mu^k rule, and exchanges theta_hat
with its neighbors. This is the natural online analogue of Algorithm 2 and
degenerates to an online-DKLA when v = 0, and to (online) CTA-like
diffusion when rho = 0 with neighbor averaging off.

Regret-style evaluation: instantaneous MSE on the *incoming* minibatch
(before updating on it) — the standard online-learning protocol.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core import step as step_mod


class OnlineState(NamedTuple):
    theta: jax.Array      # (N, D)
    theta_hat: jax.Array  # (N, D)
    gamma: jax.Array      # (N, D)
    step: jax.Array
    comms: jax.Array
    # policy state (per-agent bits, PRNG key); None as the class default so
    # importing this module never allocates a device array — `init_state`
    # builds it lazily, `online_coke_step`'s ensure_state covers legacy
    # callers that constructed states without a policy.
    comm: comm_mod.CommState | None = None


def init_state(num_agents: int, feature_dim: int,
               dtype=jnp.float32, policy=None) -> OnlineState:
    z = jnp.zeros((num_agents, feature_dim), dtype)
    return OnlineState(z, z, z, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32),
                       comm_mod.as_chain(policy).init_state(num_agents))


def stream_step(state: OnlineState, feats: jax.Array,
                labels: jax.Array, adjacency: jax.Array,
                schedule, *, lam: float, rho: float,
                lr: float, eta: float | None = None
                ) -> tuple[OnlineState, jax.Array]:
    """One streaming round, shared by the whole online family.
    feats: (N, b, D) fresh minibatch per agent; labels: (N, b).
    `schedule` accepts any `core.comm` policy (Chain / stage /
    CensorSchedule / None). Returns (new state, pre-update
    instantaneous MSE — the online-protocol regret sample).

    Primal update:
      eta=None — one gradient step of size `lr` on the streaming
        augmented Lagrangian (online-DKLA / online-COKE);
      eta=float — the QC-ODKLA linearized-ADMM closed form: linearize the
        local loss at theta^k, keep the consensus quadratic exact, add the
        proximal term (eta/2)||theta - theta^k||^2. Its stationarity
        condition solves to  theta^k - g / (eta + 2 rho deg_i)  with g the
        SAME augmented gradient — i.e. a gradient step with the per-agent
        stepsize 1/(eta + 2 rho deg_i). We implement it in exactly that
        subtractive form so the two modes share every other float op
        (with eta=None and stepsize lr they are bit-identical, the
        identity contract tests/test_stream.py pins).
    """
    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(schedule), rho=rho,
        exchange=lambda s, k: step_mod.dense_view(adjacency),
        primal=step_mod.stream_primal(feats, labels, lam=lam, rho=rho,
                                      lr=lr, eta=eta))
    new_state, extras = step_mod.run_step(program, state)
    return new_state, extras["inst_mse"]


def online_coke_step(state: OnlineState, feats: jax.Array,
                     labels: jax.Array, adjacency: jax.Array,
                     schedule, *, lam: float, rho: float,
                     lr: float) -> tuple[OnlineState, jax.Array]:
    """The legacy spelling of `stream_step` with the gradient primal."""
    return stream_step(state, feats, labels, adjacency, schedule,
                       lam=lam, rho=rho, lr=lr, eta=None)


@partial(jax.jit, static_argnames=("schedule", "lam", "rho", "lr",
                                   "num_rounds", "batch_fn"))
def run_stream(state: OnlineState, adjacency: jax.Array,
               schedule, *, lam: float, rho: float,
               lr: float, num_rounds: int,
               batch_fn: Callable[[jax.Array], tuple[jax.Array, jax.Array]]):
    """Run `num_rounds` of streaming COKE; batch_fn(k) -> (feats, labels)
    must be jit-traceable (e.g. slices of a pre-featurized stream)."""
    # align the carried policy state with the schedule's chain before the
    # scan, so legacy callers that init_state() without a policy still work
    state = state._replace(comm=comm_mod.as_chain(schedule).ensure_state(
        state.comm, state.theta.shape[0]))

    def body(state, k):
        feats, labels = batch_fn(k)
        state, mse = online_coke_step(state, feats, labels, adjacency,
                                      schedule, lam=lam, rho=rho, lr=lr)
        return state, (mse, state.comms)

    state, (mse, comms) = jax.lax.scan(body, state,
                                       jnp.arange(num_rounds))
    return state, mse, comms
