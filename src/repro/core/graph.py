"""Network topology for the decentralized problem.

The paper assumes an undirected, connected graph G = (N, C, A) (Assumption 1).
We provide:
  * Erdos-Renyi graphs (the paper's synthetic setup: N=20, p=0.3, connected),
  * ring / k-circulant graphs (the TPU-native topology: neighbor exchange maps
    onto `lax.ppermute` over the `data` mesh axis),
  * incidence matrices S_+ (unsigned) and S_- (signed) and their singular
    values, which parameterize the rho-condition of Theorem 2,
  * an admissible-rho helper implementing Eq. (23)/(32).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected connected graph with dense adjacency (small N)."""

    adjacency: np.ndarray  # (N, N) 0/1 symmetric, zero diagonal

    @property
    def num_agents(self) -> int:
        return self.adjacency.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    # ---- incidence matrices (Shi et al. 2014 notation) -------------------
    def edge_list(self) -> list[tuple[int, int]]:
        N = self.num_agents
        return [
            (i, n)
            for i in range(N)
            for n in range(i + 1, N)
            if self.adjacency[i, n]
        ]

    def incidence(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (S_plus, S_minus): unsigned / signed edge-node incidence.

        Rows are *directed* edge duplicates (both orientations), matching the
        2|C| x N construction used in the decentralized-ADMM literature.
        """
        edges = self.edge_list()
        E = len(edges)
        S_plus = np.zeros((2 * E, self.num_agents))
        S_minus = np.zeros((2 * E, self.num_agents))
        for e, (i, n) in enumerate(edges):
            for row, (src, dst) in ((e, (i, n)), (e + E, (n, i))):
                S_plus[row, src] = 1.0
                S_plus[row, dst] = 1.0
                S_minus[row, src] = 1.0
                S_minus[row, dst] = -1.0
        return S_plus, S_minus

    def sigma_terms(self) -> tuple[float, float]:
        """(sigma_max(S_+), sigma_min_nonzero(S_-)) for the Thm-2 rho bound."""
        S_plus, S_minus = self.incidence()
        smax = float(np.linalg.svd(S_plus, compute_uv=False)[0])
        sv = np.linalg.svd(S_minus, compute_uv=False)
        nonzero = sv[sv > 1e-9]
        return smax, float(nonzero[-1])

    def is_connected(self) -> bool:
        N = self.num_agents
        seen = {0}
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for n in np.nonzero(self.adjacency[i])[0]:
                if int(n) not in seen:
                    seen.add(int(n))
                    frontier.append(int(n))
        return len(seen) == N


def erdos_renyi(num_agents: int, p: float, seed: int = 0) -> Graph:
    """Connected ER graph (redraw until connected — paper's synthetic setup)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        upper = rng.random((num_agents, num_agents)) < p
        adj = np.triu(upper, 1).astype(np.float64)
        adj = adj + adj.T
        g = Graph(adjacency=adj)
        if g.is_connected():
            return g
    raise RuntimeError("failed to draw a connected ER graph; increase p")


def ring(num_agents: int) -> Graph:
    """1-D ring — the TPU-ICI-native consensus topology."""
    return circulant(num_agents, offsets=(1,))


def circulant(num_agents: int, offsets: tuple[int, ...]) -> Graph:
    """k-regular circulant graph: agent i ~ i +/- o for each offset o.

    Circulant graphs are exactly the topologies implementable as a fixed set
    of `lax.ppermute` shifts, i.e. they lower to `collective-permute` on TPU.
    """
    adj = np.zeros((num_agents, num_agents))
    for o in offsets:
        if not 0 < o < num_agents:
            raise ValueError(f"offset {o} out of range for N={num_agents}")
        for i in range(num_agents):
            adj[i, (i + o) % num_agents] = 1.0
            adj[(i + o) % num_agents, i] = 1.0
    return Graph(adjacency=adj)


def fully_connected(num_agents: int) -> Graph:
    adj = np.ones((num_agents, num_agents)) - np.eye(num_agents)
    return Graph(adjacency=adj)


@partial(jax.tree_util.register_dataclass,
         data_fields=("adjacencies",), meta_fields=("offsets",))
@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """Time-varying consensus topology: iteration k (1-based) runs on graph
    `adjacencies[(k - 1) % M]`, cycling through the M stacked graphs.

    `offsets` is the circulant lowering for the spmd/fused ring runtime —
    one offset tuple per graph, each realizable as `jnp.roll` shifts
    (collective-permute). It is required by the spmd backend and None for
    general (e.g. Erdos-Renyi) schedules, which only the simulator runs.

    The adjacency stack is pytree *data*: the per-iteration graph selection
    traces into the compiled fit loop (a gather, not a retrace).
    """

    adjacencies: jax.Array  # (M, N, N) float
    offsets: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.offsets is not None:
            object.__setattr__(
                self, "offsets", tuple(tuple(o) for o in self.offsets))

    @property
    def num_graphs(self) -> int:
        return self.adjacencies.shape[0]

    @property
    def num_agents(self) -> int:
        return self.adjacencies.shape[-1]

    def index(self, k) -> jax.Array:
        """Graph index for (1-based, possibly traced) iteration k."""
        return (k - 1) % self.num_graphs

    def at(self, k) -> jax.Array:
        """Adjacency in effect at iteration k."""
        return self.adjacencies[self.index(k)]

    @classmethod
    def from_graphs(cls, graphs, offsets=None) -> "TopologySchedule":
        """Stack a sequence of `Graph`s (equal N) into a schedule."""
        adj = jnp.stack([jnp.asarray(g.adjacency, jnp.float32)
                         for g in graphs])
        return cls(adjacencies=adj, offsets=offsets)

    @classmethod
    def circulant_cycle(cls, num_agents: int,
                        offset_variants) -> "TopologySchedule":
        """Cycle through circulant graphs — the schedule form the spmd ring
        runtime lowers (one `lax.switch` branch of permutes per variant)."""
        variants = tuple(tuple(v) for v in offset_variants)
        return cls.from_graphs(
            [circulant(num_agents, off) for off in variants],
            offsets=variants)


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Doubly-stochastic mixing matrix used by the CTA diffusion baseline."""
    A = graph.adjacency
    deg = graph.degrees
    N = graph.num_agents
    W = np.zeros((N, N))
    for i in range(N):
        for n in range(N):
            if A[i, n]:
                W[i, n] = 1.0 / (1.0 + max(deg[i], deg[n]))
        W[i, i] = 1.0 - W[i].sum()
    return W


def admissible_rho(
    graph: Graph,
    m_R: float,
    M_R: float,
    nu: float = 2.0,
    eta1: float = 1.0,
    eta2: float = 1.0,
    eta3: float | None = None,
) -> float:
    """Largest rho satisfying the Theorem-2 bound (Eq. 23/32), or a safe
    fallback when the constants make the third term vacuous.

    eta3 defaults to the value that keeps the third term positive:
    eta3 < m_R * sigma_min^2(S_-) / (nu * M_R^2).
    """
    smax, smin = graph.sigma_terms()
    if eta3 is None:
        eta3 = 0.5 * m_R * smin**2 / (nu * M_R**2)
    t1 = 4.0 * m_R / eta1
    t2 = (nu - 1.0) * smin**2 / (nu * eta3 * smax**2)
    gap = m_R - eta3 * nu * M_R**2 / smin**2
    t3 = gap / (eta1 / 4.0 + eta2 * smax**2 / 8.0)
    rho = min(t1, t2, t3)
    if rho <= 0:
        raise ValueError("no admissible rho; loosen eta constants")
    return rho
