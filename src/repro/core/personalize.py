"""Personalization: learned collaboration graphs + per-agent models.

Full consensus on a human-chosen topology is exactly wrong when agents
hold heterogeneous (non-IID) data — the regime Koppel et al. (arXiv
1710.04062) describe as functions that only *partially* agree across a
network. Following Dada (Zantedeschi et al., AISTATS 2020), this module
alternates the existing DKLA/COKE/online ADMM steps with a graph-update
step: pairwise affinities over the agent-stacked (N, D) thetas are
sparsified to a mutual top-k collaboration graph whose *weights* rescale
the consensus penalty — agents with similar models pull hard on each
other, agents in different clusters decouple and keep distinct models.

The machinery is deliberately thin: the learned adjacency threads into
the SAME update equations every backend already runs (`deg_i = sum_j
w_ij`, `nbr_sum = A @ theta_hat`, dual `gamma += rho (deg theta_hat -
A theta_hat)`), so strict consensus (w_ij in {0, 1} on the configured
graph) relaxes to a similarity-weighted proximity penalty with no new
update rule. `personalization=None` leaves every code path untouched —
bit-identical to the consensus trajectories (the conformance pin).

Affinity computation is row-blocked (`lax.map` over (B, N) tiles): no
full (N, N) affinity matrix is ever materialized — only the sparse
top-k result, scattered into the dense adjacency the existing backends
consume (the simulator's neighbor exchange is an adjacency matmul
already).

Graph-update cadence: iteration k refreshes the graph iff k > warmup
and (k - warmup - 1) % every == 0 — the first refresh happens AT
iteration warmup + 1, so iterations 1..warmup are bit-identical to the
static-topology run (the prefix-invariance pin), and warmup >=
num_iters never refreshes at all (bit-identical end to end).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core.admm import (COKEState, Problem, _primal_cg,
                             _primal_gradient)
from repro.core.gossip import GossipPlan, _mask_rows, participation_mask
from repro.core.online import OnlineState

AFFINITY_KINDS = ("rbf", "cosine")

#: guard for zero distances / zero norms in the affinity kernels
_EPS = 1e-12


@partial(jax.tree_util.register_dataclass, data_fields=("scale",),
         meta_fields=("k", "every", "warmup", "affinity"))
@dataclasses.dataclass(frozen=True)
class Personalization:
    """The `FitConfig.personalization` axis: how and when the
    collaboration graph is learned from the agent-stacked thetas.

    k        — neighbors kept per agent (mutual top-k sparsification;
               learned row degrees are <= k).
    every    — graph-refresh period in iterations.
    warmup   — iterations run on the configured static graph before the
               first refresh (thetas start identical — let them separate
               before inferring affinity from them).
    affinity — "rbf": w_ij = exp(-||t_i - t_j||^2 / s_ij) ranked by
               distance; "cosine": clipped cosine similarity.
    scale    — rbf length scale. 0.0 (default) = local auto-scaling
               (Zelnik-Manor & Perona): s_ij = sigma_i sigma_j with
               sigma_i the distance to agent i's k-th neighbor — scale-
               free, so it needs no tuning as thetas grow. scale > 0
               fixes s_ij = 2 scale^2. Traced data: a scale sweep shares
               one compiled fit loop.
    """

    k: int = 3
    every: int = 10
    warmup: int = 10
    affinity: str = "rbf"
    scale: float = 0.0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"personalization needs k >= 1, got {self.k}")
        if self.every < 1:
            raise ValueError(
                f"graph-refresh period must be >= 1, got {self.every}")
        if self.warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {self.warmup}")
        if self.affinity not in AFFINITY_KINDS:
            raise ValueError(
                f"unknown affinity {self.affinity!r}; choose from "
                f"{AFFINITY_KINDS}")
        if isinstance(self.scale, (int, float)) and self.scale < 0:
            raise ValueError(
                f"scale must be >= 0 (0 = local auto-scaling), got "
                f"{self.scale}")


class PersonalizedState(NamedTuple):
    """The ADMM solver state plus the current learned adjacency — what a
    personalized fit carries through the scan."""

    inner: COKEState
    adjacency: jax.Array   # (N, N) weighted, symmetric, zero-diagonal


# ---------------------------------------------------------------------------
# Learning the graph
# ---------------------------------------------------------------------------

def topk_neighbors(thetas: jax.Array, k: int, affinity: str = "rbf",
                   scale=0.0, block: int = 128
                   ) -> tuple[jax.Array, jax.Array]:
    """Each agent's k most-affine peers from the (N, D) theta stack.

    Returns (idx, w): (N, k) int32 neighbor indices (self excluded,
    best first) and (N, k) float32 affinity weights in [0, 1].

    Scratch is one (B, N) distance tile at a time (`lax.map` over row
    blocks) — the full (N, N) affinity matrix is never materialized,
    so the graph update stays O(N^2 D / B) flops but O(B N) memory.
    """
    N, _ = thetas.shape
    if not 1 <= k <= N - 1:
        raise ValueError(
            f"top-k needs 1 <= k <= N-1 (k={k}, N={N} agents)")
    t = thetas.astype(jnp.float32)
    sq = jnp.sum(t * t, axis=1)                      # (N,)
    B = min(block, N)
    num_blocks = -(-N // B)
    col = jnp.arange(N)

    def one_block(i0):
        rows = jnp.minimum(i0 + jnp.arange(B), N - 1)
        dots = t[rows] @ t.T                         # (B, N)
        if affinity == "rbf":
            d2 = jnp.maximum(sq[rows][:, None] + sq[None, :] - 2.0 * dots,
                             0.0)
            score = -d2
            val = d2
        else:
            norms = jnp.sqrt(sq)
            denom = jnp.maximum(norms[rows][:, None] * norms[None, :],
                                _EPS)
            cos = jnp.clip(dots / denom, 0.0, 1.0)
            score = cos
            val = cos
        score = jnp.where(rows[:, None] == col[None, :], -jnp.inf, score)
        top_score, top_idx = jax.lax.top_k(score, k)
        return top_idx.astype(jnp.int32), jnp.take_along_axis(
            val, top_idx, axis=1)

    idx, val = jax.lax.map(one_block, jnp.arange(num_blocks) * B)
    idx = idx.reshape(num_blocks * B, k)[:N]
    val = val.reshape(num_blocks * B, k)[:N]

    if affinity == "cosine":
        return idx, val
    # rbf: turn the ascending-d2 top-k into weights. Local auto-scaling
    # (scale == 0): sigma_i^2 = d2 to the k-th neighbor, w_ij =
    # exp(-d2_ij / (sigma_i sigma_j)); fixed scale > 0: w_ij =
    # exp(-d2_ij / (2 scale^2)). jnp.where keeps `scale` traced data.
    sig2 = val[:, -1]                                # (N,)
    local = jnp.maximum(jnp.sqrt(sig2[:, None] * sig2[idx]), _EPS)
    s = jnp.asarray(scale, jnp.float32)
    denom = jnp.where(s > 0, jnp.maximum(2.0 * s * s, _EPS), local)
    return idx, jnp.exp(-val / denom)


def learned_adjacency(pz: Personalization, thetas: jax.Array) -> jax.Array:
    """The mutual top-k collaboration graph as a dense weighted (N, N)
    adjacency — symmetric, zero diagonal, row degrees <= pz.k (the
    property-test contract): edge (i, j) survives only when i and j
    BOTH rank each other top-k, with weight (w_ij + w_ji) / 2."""
    idx, w = topk_neighbors(thetas, pz.k, pz.affinity, pz.scale)
    N = thetas.shape[0]
    rows = jnp.arange(N)[:, None]
    directed = jnp.zeros((N, N), jnp.float32).at[rows, idx].set(w)
    mutual = (directed > 0) & (directed.T > 0)
    return jnp.where(mutual, 0.5 * (directed + directed.T), 0.0)


def should_update(pz: Personalization, k) -> jax.Array:
    """Traced bool: does iteration k (1-based) refresh the graph?"""
    k = jnp.asarray(k, jnp.int32)
    return (k > pz.warmup) & ((k - pz.warmup - 1) % pz.every == 0)


def maybe_update(pz: Personalization, thetas: jax.Array, k,
                 adjacency: jax.Array) -> jax.Array:
    """The per-iteration graph step: relearn the adjacency from the
    current thetas on refresh iterations, carry it unchanged otherwise
    (one lax.cond — off-iterations pay nothing)."""
    return jax.lax.cond(
        should_update(pz, k),
        lambda t: learned_adjacency(pz, t).astype(adjacency.dtype),
        lambda t: adjacency, thetas)


def graph_recovery(adjacency: jax.Array, clusters) -> jax.Array:
    """Fraction of learned edge mass that is intra-cluster, in [0, 1] —
    the graph-recovery score against ground-truth task labels (1.0 =
    every learned edge connects same-task agents)."""
    c = jnp.asarray(clusters)
    same = c[:, None] == c[None, :]
    total = jnp.sum(adjacency)
    intra = jnp.sum(jnp.where(same, adjacency, 0.0))
    return jnp.where(total > 0, intra / jnp.maximum(total, _EPS), 0.0)


# ---------------------------------------------------------------------------
# Personalized gossip steps (dense learned graph)
#
# The static-graph gossip path reads the topology through a host-built
# NeighborTable — which cannot follow a graph relearned inside the scan.
# These dense-masked steps mirror core.gossip's update structure exactly
# (participation mask, structurally-silent broadcast, delayed duals) with
# `A @ x` neighbor sums, so participation = 1.0 reproduces the
# synchronous personalized step bit-for-bit (the degeneracy contract).
# ---------------------------------------------------------------------------

def gossip_coke_step_dense(
    problem: Problem,
    policy,
    pz: Personalization,
    state: PersonalizedState,
    plan: GossipPlan,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
    primal: str = "cg",
    cg_tol: float = 1e-8,
    cg_maxiter: int = 64,
) -> PersonalizedState:
    """One asynchronous personalized ADMM iteration: refresh the learned
    graph if due, then the sampled participants run the (21a) primal +
    policy-governed broadcast + delayed (21b) dual on it."""
    s = state.inner
    k = s.step + 1
    A = maybe_update(pz, s.theta, k, state.adjacency)
    chain = comm_mod.as_chain(policy)
    N = s.theta.shape[0]
    comm_state = chain.ensure_state(s.comm, N)

    deg = jnp.sum(A, axis=1)
    nbr_hat = A @ s.theta_hat

    if primal == "cg":
        theta_new = _primal_cg(problem, s.gamma, s.theta_hat, nbr_hat,
                               deg, theta0=s.theta, tol=cg_tol,
                               maxiter=cg_maxiter)
    else:
        theta_new = _primal_gradient(problem, inner_steps, inner_lr,
                                     s.theta, s.gamma, s.theta_hat,
                                     nbr_hat, deg)

    m = participation_mask(comm_state.key, k, N, plan)
    theta = _mask_rows(m, theta_new, s.theta)
    theta_hat, send, comm_state = chain.apply(theta, s.theta_hat, k,
                                              comm_state, active=m)
    gamma = _mask_rows(
        m, s.gamma + problem.rho * (deg[:, None] * theta_hat
                                    - A @ theta_hat), s.gamma)
    inner = COKEState(
        theta=theta, theta_hat=theta_hat, gamma=gamma, step=k,
        comms=s.comms + jnp.sum(send.astype(jnp.int32)), comm=comm_state)
    return PersonalizedState(inner, A)


def gossip_stream_step_dense(
    state: OnlineState,
    feats: jax.Array,
    labels: jax.Array,
    adjacency: jax.Array,
    schedule,
    plan: GossipPlan,
    *,
    lam: float,
    rho: float,
    lr: float,
    eta: float | None = None,
) -> tuple[OnlineState, jax.Array]:
    """The asynchronous streaming round on a (learned) dense graph —
    `core.gossip.gossip_stream_step` with `A @ x` in place of the static
    neighbor-table gathers. The caller owns the graph refresh (the
    adjacency rides in the solver's fit state, not the OnlineState)."""
    chain = comm_mod.as_chain(schedule)
    N = feats.shape[0]
    k = state.step + 1
    comm_state = chain.ensure_state(state.comm, N)

    deg = jnp.sum(adjacency, axis=1)
    preds = jnp.einsum("nbd,nd->nb", feats, state.theta)
    inst_mse = jnp.mean((labels - preds) ** 2)

    resid = preds - labels
    g_data = 2.0 * jnp.einsum("nb,nbd->nd", resid, feats) / feats.shape[1]
    nbr_sum = adjacency @ state.theta_hat
    g = (g_data + (2.0 * lam / N) * state.theta
         + 2.0 * rho * deg[:, None] * state.theta
         + state.gamma
         - rho * (deg[:, None] * state.theta_hat + nbr_sum))
    if eta is None:
        theta_new = state.theta - lr * g
    else:
        theta_new = state.theta - g / (eta + 2.0 * rho * deg[:, None])

    m = participation_mask(comm_state.key, k, N, plan)
    theta = _mask_rows(m, theta_new, state.theta)
    theta_hat, send, comm_state = chain.apply(theta, state.theta_hat, k,
                                              comm_state, active=m)
    gamma = _mask_rows(
        m, state.gamma + rho * (deg[:, None] * theta_hat
                                - adjacency @ theta_hat), state.gamma)
    return OnlineState(theta, theta_hat, gamma, k,
                       state.comms + jnp.sum(send.astype(jnp.int32)),
                       comm_state), inst_mse
