"""Personalization: learned collaboration graphs + per-agent models.

Full consensus on a human-chosen topology is exactly wrong when agents
hold heterogeneous (non-IID) data — the regime Koppel et al. (arXiv
1710.04062) describe as functions that only *partially* agree across a
network. Following Dada (Zantedeschi et al., AISTATS 2020), this module
alternates the existing DKLA/COKE/online ADMM steps with a graph-update
step: pairwise affinities over the agent-stacked (N, D) thetas are
sparsified to a mutual top-k collaboration graph whose *weights* rescale
the consensus penalty — agents with similar models pull hard on each
other, agents in different clusters decouple and keep distinct models.

The machinery is deliberately thin: the learned adjacency threads into
the SAME update equations every backend already runs (`deg_i = sum_j
w_ij`, `nbr_sum = A @ theta_hat`, dual `gamma += rho (deg theta_hat -
A theta_hat)`), so strict consensus (w_ij in {0, 1} on the configured
graph) relaxes to a similarity-weighted proximity penalty with no new
update rule. `personalization=None` leaves every code path untouched —
bit-identical to the consensus trajectories (the conformance pin).

Affinity computation is row-blocked (`lax.map` over (B, N) tiles): no
full (N, N) affinity matrix is ever materialized — only the sparse
top-k result, scattered into the dense adjacency the existing backends
consume (the simulator's neighbor exchange is an adjacency matmul
already).

Graph-update cadence: iteration k refreshes the graph iff k > warmup
and (k - warmup - 1) % every == 0 — the first refresh happens AT
iteration warmup + 1, so iterations 1..warmup are bit-identical to the
static-topology run (the prefix-invariance pin), and warmup >=
num_iters never refreshes at all (bit-identical end to end).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core import step as step_mod
from repro.core.admm import COKEState, Problem, _primal_stage
from repro.core.gossip import GossipPlan
from repro.core.online import OnlineState

AFFINITY_KINDS = ("rbf", "cosine")

#: guard for zero distances / zero norms in the affinity kernels
_EPS = 1e-12


@partial(jax.tree_util.register_dataclass, data_fields=("scale",),
         meta_fields=("k", "every", "warmup", "affinity"))
@dataclasses.dataclass(frozen=True)
class Personalization:
    """The `FitConfig.personalization` axis: how and when the
    collaboration graph is learned from the agent-stacked thetas.

    k        — neighbors kept per agent (mutual top-k sparsification;
               learned row degrees are <= k).
    every    — graph-refresh period in iterations.
    warmup   — iterations run on the configured static graph before the
               first refresh (thetas start identical — let them separate
               before inferring affinity from them).
    affinity — "rbf": w_ij = exp(-||t_i - t_j||^2 / s_ij) ranked by
               distance; "cosine": clipped cosine similarity.
    scale    — rbf length scale. 0.0 (default) = local auto-scaling
               (Zelnik-Manor & Perona): s_ij = sigma_i sigma_j with
               sigma_i the distance to agent i's k-th neighbor — scale-
               free, so it needs no tuning as thetas grow. scale > 0
               fixes s_ij = 2 scale^2. Traced data: a scale sweep shares
               one compiled fit loop.
    """

    k: int = 3
    every: int = 10
    warmup: int = 10
    affinity: str = "rbf"
    scale: float = 0.0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"personalization needs k >= 1, got {self.k}")
        if self.every < 1:
            raise ValueError(
                f"graph-refresh period must be >= 1, got {self.every}")
        if self.warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {self.warmup}")
        if self.affinity not in AFFINITY_KINDS:
            raise ValueError(
                f"unknown affinity {self.affinity!r}; choose from "
                f"{AFFINITY_KINDS}")
        if isinstance(self.scale, (int, float)) and self.scale < 0:
            raise ValueError(
                f"scale must be >= 0 (0 = local auto-scaling), got "
                f"{self.scale}")


class PersonalizedState(NamedTuple):
    """The ADMM solver state plus the current learned adjacency — what a
    personalized fit carries through the scan."""

    inner: COKEState
    adjacency: jax.Array   # (N, N) weighted, symmetric, zero-diagonal


# ---------------------------------------------------------------------------
# Learning the graph
# ---------------------------------------------------------------------------

def topk_neighbors(thetas: jax.Array, k: int, affinity: str = "rbf",
                   scale=0.0, block: int = 128
                   ) -> tuple[jax.Array, jax.Array]:
    """Each agent's k most-affine peers from the (N, D) theta stack.

    Returns (idx, w): (N, k) int32 neighbor indices (self excluded,
    best first) and (N, k) float32 affinity weights in [0, 1].

    Scratch is one (B, N) distance tile at a time (`lax.map` over row
    blocks) — the full (N, N) affinity matrix is never materialized,
    so the graph update stays O(N^2 D / B) flops but O(B N) memory.
    """
    N, _ = thetas.shape
    if not 1 <= k <= N - 1:
        raise ValueError(
            f"top-k needs 1 <= k <= N-1 (k={k}, N={N} agents)")
    t = thetas.astype(jnp.float32)
    sq = jnp.sum(t * t, axis=1)                      # (N,)
    B = min(block, N)
    num_blocks = -(-N // B)
    col = jnp.arange(N)

    def one_block(i0):
        rows = jnp.minimum(i0 + jnp.arange(B), N - 1)
        dots = t[rows] @ t.T                         # (B, N)
        if affinity == "rbf":
            d2 = jnp.maximum(sq[rows][:, None] + sq[None, :] - 2.0 * dots,
                             0.0)
            score = -d2
            val = d2
        else:
            norms = jnp.sqrt(sq)
            denom = jnp.maximum(norms[rows][:, None] * norms[None, :],
                                _EPS)
            cos = jnp.clip(dots / denom, 0.0, 1.0)
            score = cos
            val = cos
        score = jnp.where(rows[:, None] == col[None, :], -jnp.inf, score)
        top_score, top_idx = jax.lax.top_k(score, k)
        return top_idx.astype(jnp.int32), jnp.take_along_axis(
            val, top_idx, axis=1)

    idx, val = jax.lax.map(one_block, jnp.arange(num_blocks) * B)
    idx = idx.reshape(num_blocks * B, k)[:N]
    val = val.reshape(num_blocks * B, k)[:N]

    if affinity == "cosine":
        return idx, val
    # rbf: turn the ascending-d2 top-k into weights. Local auto-scaling
    # (scale == 0): sigma_i^2 = d2 to the k-th neighbor, w_ij =
    # exp(-d2_ij / (sigma_i sigma_j)); fixed scale > 0: w_ij =
    # exp(-d2_ij / (2 scale^2)). jnp.where keeps `scale` traced data.
    sig2 = val[:, -1]                                # (N,)
    local = jnp.maximum(jnp.sqrt(sig2[:, None] * sig2[idx]), _EPS)
    s = jnp.asarray(scale, jnp.float32)
    denom = jnp.where(s > 0, jnp.maximum(2.0 * s * s, _EPS), local)
    return idx, jnp.exp(-val / denom)


def learned_adjacency(pz: Personalization, thetas: jax.Array) -> jax.Array:
    """The mutual top-k collaboration graph as a dense weighted (N, N)
    adjacency — symmetric, zero diagonal, row degrees <= pz.k (the
    property-test contract): edge (i, j) survives only when i and j
    BOTH rank each other top-k, with weight (w_ij + w_ji) / 2."""
    idx, w = topk_neighbors(thetas, pz.k, pz.affinity, pz.scale)
    N = thetas.shape[0]
    rows = jnp.arange(N)[:, None]
    directed = jnp.zeros((N, N), jnp.float32).at[rows, idx].set(w)
    mutual = (directed > 0) & (directed.T > 0)
    return jnp.where(mutual, 0.5 * (directed + directed.T), 0.0)


def should_update(pz: Personalization, k) -> jax.Array:
    """Traced bool: does iteration k (1-based) refresh the graph?"""
    k = jnp.asarray(k, jnp.int32)
    return (k > pz.warmup) & ((k - pz.warmup - 1) % pz.every == 0)


def maybe_update(pz: Personalization, thetas: jax.Array, k,
                 adjacency: jax.Array) -> jax.Array:
    """The per-iteration graph step: relearn the adjacency from the
    current thetas on refresh iterations, carry it unchanged otherwise
    (one lax.cond — off-iterations pay nothing)."""
    return jax.lax.cond(
        should_update(pz, k),
        lambda t: learned_adjacency(pz, t).astype(adjacency.dtype),
        lambda t: adjacency, thetas)


def graph_recovery(adjacency: jax.Array, clusters) -> jax.Array:
    """Fraction of learned edge mass that is intra-cluster, in [0, 1] —
    the graph-recovery score against ground-truth task labels (1.0 =
    every learned edge connects same-task agents)."""
    c = jnp.asarray(clusters)
    same = c[:, None] == c[None, :]
    total = jnp.sum(adjacency)
    intra = jnp.sum(jnp.where(same, adjacency, 0.0))
    return jnp.where(total > 0, intra / jnp.maximum(total, _EPS), 0.0)


# ---------------------------------------------------------------------------
# Personalized gossip steps (dense learned graph)
#
# The static-graph gossip path reads the topology through a host-built
# NeighborTable — which cannot follow a graph relearned inside the scan.
# These dense-masked steps mirror core.gossip's update structure exactly
# (participation mask, structurally-silent broadcast, delayed duals) with
# `A @ x` neighbor sums, so participation = 1.0 reproduces the
# synchronous personalized step bit-for-bit (the degeneracy contract).
# ---------------------------------------------------------------------------

def gossip_coke_step_dense(
    problem: Problem,
    policy,
    pz: Personalization,
    state: PersonalizedState,
    plan: GossipPlan,
    inner_steps: int = 50,
    inner_lr: float = 0.1,
    primal: str = "cg",
    cg_tol: float = 1e-8,
    cg_maxiter: int = 64,
) -> PersonalizedState:
    """One asynchronous personalized ADMM iteration: refresh the learned
    graph if due, then the sampled participants run the (21a) primal +
    policy-governed broadcast + delayed (21b) dual on it."""
    s = state.inner
    A = maybe_update(pz, s.theta, s.step + 1, state.adjacency)
    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(policy), rho=problem.rho,
        exchange=lambda st, k: step_mod.dense_view(A),
        primal=_primal_stage(problem, primal, inner_steps=inner_steps,
                             inner_lr=inner_lr, cg_tol=cg_tol,
                             cg_maxiter=cg_maxiter),
        comm_decide=step_mod.sampled_stage(plan))
    inner, _ = step_mod.run_step(program, s)
    return PersonalizedState(inner, A)


def gossip_stream_step_dense(
    state: OnlineState,
    feats: jax.Array,
    labels: jax.Array,
    adjacency: jax.Array,
    schedule,
    plan: GossipPlan,
    *,
    lam: float,
    rho: float,
    lr: float,
    eta: float | None = None,
) -> tuple[OnlineState, jax.Array]:
    """The asynchronous streaming round on a (learned) dense graph —
    `core.gossip.gossip_stream_step` with `A @ x` in place of the static
    neighbor-table gathers. The caller owns the graph refresh (the
    adjacency rides in the solver's fit state, not the OnlineState)."""
    program = step_mod.StepProgram(
        chain=comm_mod.as_chain(schedule), rho=rho,
        exchange=lambda st, k: step_mod.dense_view(adjacency),
        primal=step_mod.stream_primal(feats, labels, lam=lam, rho=rho,
                                      lr=lr, eta=eta),
        comm_decide=step_mod.sampled_stage(plan))
    new_state, extras = step_mod.run_step(program, state)
    return new_state, extras["inst_mse"]
