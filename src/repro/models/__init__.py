"""Model zoo: unified config + blocks covering the ten assigned architectures."""
from repro.models.common import ModelConfig  # noqa: F401
from repro.models import attention, blocks, common, model, moe, ssm  # noqa: F401
