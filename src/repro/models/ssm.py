"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): quadratic
attention-like compute inside chunks of length Q, linear recurrence across
chunk boundaries — computed under a `lax.scan` over chunks so live memory is
O(B * Q^2 * H), not O(B * S * Q * H).

Decode is the O(1) recurrent update on the (B, H, P, N) state — this is what
makes `long_500k` natural for the SSM/hybrid architectures.

Projection layout (a §Perf finding, see EXPERIMENTS.md): the reference
implementation fuses z|x|B|C|dt into one in_proj whose column sharding
misaligns with the semantic split, so tensor-parallel SPMD all-gathers the
whole (B, S, 2*d_inner + 2N + H) projection every layer. We keep SEPARATE
head-aligned projections (w_z, w_x sharded on d_inner; w_bc replicated —
B/C are shared across heads; w_dt sharded on heads), which keeps the conv,
the SSD scan, the gating, and the norm shard-local and leaves a single
all-reduce per layer at out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rms_norm


class SSMCache(NamedTuple):
    conv_x: jax.Array   # (B, W-1, d_inner) trailing conv inputs (x path)
    conv_bc: jax.Array  # (B, W-1, 2N) trailing conv inputs (B/C path)
    state: jax.Array    # (B, H, P, N) recurrent state


def init_ssm_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    # dt bias init so softplus(dt_bias) spans ~[1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[0], (H,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "w_z": dense_init(ks[1], (d, di), cfg.dtype),
        "w_x": dense_init(ks[2], (d, di), cfg.dtype),
        "w_bc": dense_init(ks[3], (d, 2 * N), cfg.dtype),
        "w_dt": dense_init(ks[4], (d, H), cfg.dtype),
        "conv_x": dense_init(ks[5], (W, di), cfg.dtype, fan_in=W),
        "conv_bc": dense_init(ks[6], (W, 2 * N), cfg.dtype, fan_in=W),
        "conv_bx": jnp.zeros((di,), cfg.dtype),
        "conv_bbc": jnp.zeros((2 * N,), cfg.dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.ones((di,), cfg.dtype),
        "out_proj": dense_init(ks[7], (di, d), cfg.dtype, fan_in=di),
    }


def _causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array | None = None):
    """Depthwise causal conv along S. xc: (B,S,ch); w: (W,ch).
    prev: (B, W-1, ch) trailing context (decode) or None (zero left-pad)."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xc.shape[0], W - 1, xc.shape[-1]), xc.dtype)
    xp = jnp.concatenate([prev, xc], axis=1)
    out = sum(xp[:, i:i + xc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b), xp[:, -(W - 1):]


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: jax.Array | None = None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    xc = x.reshape(B_, nc, Q, H, P).swapaxes(0, 1)     # (nc,B,Q,H,P)
    dtc = dt.reshape(B_, nc, Q, H).swapaxes(0, 1)      # (nc,B,Q,H)
    Bc = Bm.reshape(B_, nc, Q, N).swapaxes(0, 1)
    Cc = Cm.reshape(B_, nc, Q, N).swapaxes(0, 1)

    if init_state is None:
        init_state = jnp.zeros((B_, H, P, N), jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        xq, dtq, Bq, Cq = inp                          # (B,Q,...)
        dA = dtq * A                                    # (B,Q,H) negative
        cum = jnp.cumsum(dA, axis=1)                    # (B,Q,H)
        # intra-chunk quadratic part
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,i,j,H)
        cb = jnp.einsum("bin,bjn->bij", Cq, Bq,
                        preferred_element_type=jnp.float32)
        scores = cb[..., None] * decay * dtq[:, None, :, :]       # (B,i,j,H)
        scores = jnp.where(tri[None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores,
                             xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        state_decay = jnp.exp(cum)                       # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cq, state,
                             state_decay)
        # chunk-end state update
        rem = jnp.exp(cum[:, -1:, :] - cum)              # (B,Q,H)
        contrib = jnp.einsum("bjn,bjhp,bjh->bhpn", Bq,
                             xq.astype(jnp.float32), rem * dtq)
        total_decay = jnp.exp(cum[:, -1, :])             # (B,H)
        state_new = state * total_decay[:, :, None, None] + contrib
        return state_new, (y_intra + y_inter)

    state, ys = jax.lax.scan(chunk_step, init_state, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(B_, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), state


def _project(params: dict, cfg: ModelConfig, x: jax.Array):
    z = x @ params["w_z"]
    xs = x @ params["w_x"]
    bc = x @ params["w_bc"]
    dt = x @ params["w_dt"]
    return z, xs, bc, dt


def ssm_forward(params: dict, cfg: ModelConfig, x: jax.Array,
                return_cache: bool = False):
    """Full-sequence mixer. x: (B,S,d) -> (B,S,d) [, SSMCache]."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, bc, dt = _project(params, cfg, x)
    xs, tail_x = _causal_conv(xs, params["conv_x"], params["conv_bx"])
    bc, tail_bc = _causal_conv(bc, params["conv_bc"], params["conv_bbc"])
    xs = xs.reshape(B, S, H, P)
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_cache:
        return out, SSMCache(conv_x=tail_x, conv_bc=tail_bc, state=state)
    return out


def ssm_decode(params: dict, cfg: ModelConfig, x: jax.Array,
               cache: SSMCache):
    """Single-token recurrent update. x: (B,1,d)."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, bc, dt = _project(params, cfg, x)
    xs, tail_x = _causal_conv(xs, params["conv_x"], params["conv_bx"],
                              prev=cache.conv_x)
    bc, tail_bc = _causal_conv(bc, params["conv_bc"], params["conv_bbc"],
                               prev=cache.conv_bc)
    xs1 = xs[:, 0].reshape(B, H, P)
    Bm, Cm = bc[:, 0, :N], bc[:, 0, N:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt1 * A)                                    # (B,H)
    state = (cache.state * dA[:, :, None, None]
             + jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(jnp.float32),
                          xs1.astype(jnp.float32), dt1))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + params["D"][None, :, None] * xs1.astype(jnp.float32)
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], SSMCache(conv_x=tail_x, conv_bc=tail_bc,
                                            state=state)
