"""Mixture-of-Experts layer: grouped GShard-style top-k dispatch.

Tokens are split into groups of `moe_group_size`; within a group, top-k
routing builds a one-hot dispatch tensor (S_g, E, C) with capacity
C = ceil(k * S_g / E * capacity_factor). Grouping bounds the dispatch
tensor to T * k * cf * S_g elements (vs T * k * cf * T ungrouped), keeping
the dispatch einsum a small fraction of expert FLOPs while remaining a pure
einsum program — which is what shards cleanly: group axis over `data`,
expert axis over `model` (the all-to-all shows up in the lowered HLO exactly
where a real MoE has it).

Supports shared (always-on) experts (DeepSeek-V2) alongside routed ones, and
returns the switch-transformer load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, swiglu


def init_moe_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),  # fp32 router
        "w_gate": dense_init(ks[1], (E, d, f), cfg.dtype, fan_in=d),
        "w_up": dense_init(ks[2], (E, d, f), cfg.dtype, fan_in=d),
        "w_down": dense_init(ks[3], (E, f, d), cfg.dtype, fan_in=f),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_gate"] = dense_init(ks[4], (d, fs), cfg.dtype)
        p["shared_up"] = dense_init(ks[5], (d, fs), cfg.dtype)
        p["shared_down"] = dense_init(ks[6], (fs, d), cfg.dtype, fan_in=fs)
    return p


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(cfg.top_k * group / cfg.num_experts * cfg.moe_capacity_factor)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_forward(params: dict, cfg: ModelConfig, x: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    Sg = min(cfg.moe_group_size, B * S)
    T = B * S
    assert T % Sg == 0, f"tokens {T} not divisible by group {Sg}"
    G = T // Sg
    C = _capacity(cfg, Sg)

    xg = x.reshape(G, Sg, d)
    logits = (xg.astype(jnp.float32) @ params["router"])       # (G, Sg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (G, Sg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- position-in-expert with slot priority (GShard) -------------------
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # (G,Sg,k,E)
    # earlier k-slots get priority; positions accumulate across slots
    pos_base = jnp.zeros((G, 1, E), jnp.int32)
    dispatch = jnp.zeros((G, Sg, E, C), x.dtype)
    combine = jnp.zeros((G, Sg, E, C), jnp.float32)
    for slot in range(k):
        oh = onehot[:, :, slot]                                 # (G,Sg,E)
        pos = jnp.cumsum(oh, axis=1) - oh + pos_base            # (G,Sg,E)
        keep = (pos < C) & (oh > 0)
        pos_c = jnp.clip(pos, 0, C - 1)
        disp_slot = (jax.nn.one_hot(pos_c, C, dtype=x.dtype)
                     * keep[..., None].astype(x.dtype)
                     * oh[..., None].astype(x.dtype))
        dispatch = dispatch + disp_slot
        combine = combine + disp_slot.astype(jnp.float32) * \
            gate_vals[:, :, slot, None, None]
        pos_base = pos_base + jnp.sum(oh, axis=1, keepdims=True)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)       # (G,E,C,d)
    h = swiglu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]),
               jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"]))
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), expert_out)

    # --- load-balance aux loss (switch-style) ------------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32),
        axis=(0, 1))                                             # top-1 share
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_probs)

    if cfg.num_shared_experts:
        y = y + swiglu(xg @ params["shared_gate"],
                       xg @ params["shared_up"]) @ params["shared_down"]

    return y.reshape(B, S, d), aux


def moe_forward_dense_ref(params: dict, cfg: ModelConfig, x: jax.Array
                          ) -> jax.Array:
    """Oracle: compute every expert densely, combine by normalized top-k
    gates with *no capacity drops* — tests check moe_forward matches this
    when capacity is ample."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    gates = jnp.zeros_like(probs)
    for slot in range(k):
        gates = gates + jax.nn.one_hot(expert_idx[..., slot], E) * \
            gate_vals[..., slot, None]

    h = swiglu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]),
               jnp.einsum("bsd,edf->bsef", x, params["w_up"]))
    per_expert = jnp.einsum("bsef,efd->bsed", h, params["w_down"])
    y = jnp.einsum("bse,bsed->bsd", gates.astype(x.dtype), per_expert)
    if cfg.num_shared_experts:
        y = y + swiglu(x @ params["shared_gate"],
                       x @ params["shared_up"]) @ params["shared_down"]
    return y
