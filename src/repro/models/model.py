"""Top-level language models assembled from blocks.

All ten assigned architectures reduce to three structural templates:

  * decoder-only (dense / MoE / SSM / VLM-backbone) — `lax.scan` over a
    homogeneous stacked block,
  * grouped hybrid (zamba2) — scan over groups of `shared_attn_every` SSM
    layers followed by one *weight-shared* attention block (per-application
    KV caches stay distinct),
  * encoder-decoder (seamless-m4t) — bidirectional encoder over stub frame
    embeddings + cross-attending causal decoder.

The public entry points consumed by training/serving/dry-run:
  init_params, forward(batch) -> (logits, aux), loss_fn,
  init_serve_state, prefill, decode_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.common import ModelConfig, dense_init, rms_norm


def layer_kind(cfg: ModelConfig) -> str:
    return {"moe": "moe", "ssm": "ssm", "hybrid": "ssm"}.get(
        cfg.arch_type, "dense")


def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    kind = layer_kind(cfg)
    keys = jax.random.split(key, 8)
    Vp, d = cfg.padded_vocab, cfg.d_model
    params: dict = {
        "embed": dense_init(keys[0], (Vp, d), cfg.dtype, fan_in=d),
        "final_norm": jnp.ones((d,), cfg.dtype),
        "lm_head": dense_init(keys[1], (d, Vp), cfg.dtype),
    }
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[2], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: blk.init_block_params(cfg, k, "dense"))(enc_keys)
        params["enc_norm"] = jnp.ones((d,), cfg.dtype)
        dec_keys = jax.random.split(keys[3], cfg.num_layers)
        params["decoder"] = jax.vmap(
            lambda k: blk.init_cross_block_params(cfg, k))(dec_keys)
        return params

    if cfg.arch_type == "hybrid":
        every = cfg.shared_attn_every
        assert cfg.num_layers % every == 0
        groups = cfg.num_layers // every
        lkeys = jax.random.split(keys[2], cfg.num_layers).reshape(
            groups, every, 2)
        params["blocks"] = jax.vmap(jax.vmap(
            lambda k: blk.init_block_params(cfg, k, "ssm")))(lkeys)
        params["shared_attn"] = blk.init_block_params(cfg, keys[3], "dense")
        return params

    lkeys = jax.random.split(keys[2], cfg.num_layers)
    params["blocks"] = jax.vmap(
        lambda k: blk.init_block_params(cfg, k, kind))(lkeys)
    return params


def param_shapes(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree of the parameters — dry-run stand-in, never
    allocates."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Token embedding + optional multimodal prefix. Returns (x, positions,
    text_offset) where logits[:, text_offset:] align with batch tokens."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    offset = 0
    if cfg.prefix_len and "prefix_embeds" in batch:
        x = jnp.concatenate(
            [batch["prefix_embeds"].astype(x.dtype), x], axis=1)
        offset = batch["prefix_embeds"].shape[1]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    return x, positions, offset


def _decoder_only_forward(params, cfg: ModelConfig, x, positions):
    kind = layer_kind(cfg)

    if cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def group_body(carry, gparams):
            x, aux = carry

            def layer_body(x, lp):
                y, a = blk.block_forward(lp, cfg, x, positions, "ssm")
                return y, a

            x, a_layers = jax.lax.scan(layer_body, x, gparams)
            x, a = blk.block_forward(shared, cfg, x, positions, "dense")
            return (x, aux + jnp.sum(a_layers) + a), None

        body = _maybe_remat(cfg, group_body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        return x, aux

    def layer_body(carry, lp):
        x, aux = carry
        x, a = blk.block_forward(lp, cfg, x, positions, kind)
        return (x, aux + a), None

    body = _maybe_remat(cfg, layer_body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def _encdec_forward(params, cfg: ModelConfig, batch: dict):
    # Encoder over stub frame embeddings (bidirectional).
    enc_x = batch["encoder_embeds"].astype(cfg.dtype)
    enc_pos = jnp.arange(enc_x.shape[1], dtype=jnp.int32)

    def enc_body(carry, lp):
        x, aux = carry
        x, a = blk.block_forward(lp, cfg, x, enc_pos, "dense", causal=False)
        return (x, aux + a), None

    (memory, aux), _ = jax.lax.scan(
        _maybe_remat(cfg, enc_body),
        (enc_x, jnp.zeros((), jnp.float32)), params["encoder"])
    memory = rms_norm(memory, params["enc_norm"], cfg.norm_eps)

    # Decoder with cross attention.
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def dec_body(carry, lp):
        x, aux = carry
        mk, mv = blk.cross_memory_kv(lp["cross_attn"], memory)
        x, a = blk.cross_block_forward(lp, cfg, x, pos, mk, mv)
        return (x, aux + a), None

    (x, aux2), _ = jax.lax.scan(
        _maybe_remat(cfg, dec_body),
        (x, jnp.zeros((), jnp.float32)), params["decoder"])
    return x, aux + aux2


def forward(params, cfg: ModelConfig, batch: dict):
    """-> (logits over padded vocab aligned with batch['tokens'], aux)."""
    if cfg.is_encdec:
        x, aux = _encdec_forward(params, cfg, batch)
        offset = 0
    else:
        x, positions, offset = _embed_inputs(params, cfg, batch)
        x, aux = _decoder_only_forward(params, cfg, x, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    if offset:
        logits = logits[:, offset:]
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch: dict,
            aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                               axis=-1)[..., 0]
    loss = jnp.mean(nll) + aux_weight * aux
    return loss, {"nll": jnp.mean(nll), "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_serve_state(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=None, enc_len: int = 0) -> dict:
    """Empty caches for decode-from-scratch (the dry-run decode shapes build
    these as ShapeDtypeStructs directly)."""
    dtype = dtype or cfg.dtype
    kind = layer_kind(cfg)
    if cfg.is_encdec:
        Dh = cfg.resolved_head_dim
        L = cfg.num_layers
        return {
            "self": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (L, *x.shape)),
                blk.attn_empty_cache(cfg, batch, cache_len, dtype)),
            "cross_k": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, Dh),
                                 dtype),
            "cross_v": jnp.zeros((L, batch, enc_len, cfg.num_kv_heads, Dh),
                                 dtype),
        }
    if cfg.arch_type == "hybrid":
        groups = cfg.num_layers // cfg.shared_attn_every
        ssm = blk.block_empty_cache(cfg, "ssm", batch, cache_len, dtype)
        return {
            "ssm": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (groups, cfg.shared_attn_every, *x.shape)), ssm),
            "shared": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (groups, *x.shape)),
                blk.attn_empty_cache(cfg, batch, cache_len, dtype)),
        }
    cache = blk.block_empty_cache(cfg, kind, batch, cache_len, dtype)
    return {"layers": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers, *x.shape)), cache)}


def decode_step(params, cfg: ModelConfig, token: jax.Array, state: dict,
                position: jax.Array):
    """token: (B, 1) int32 -> (logits (B, 1, Vp), new state)."""
    x = jnp.take(params["embed"], token, axis=0)
    kind = layer_kind(cfg)

    if cfg.is_encdec:
        def body(x, xs):
            lp, cache, mk, mv = xs
            x, new_cache = blk.cross_block_decode(lp, cfg, x, cache,
                                                  position, mk, mv)
            return x, new_cache

        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], state["self"],
                      state["cross_k"], state["cross_v"]))
        state = dict(state, self=new_self)
    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, xs):
            gparams, ssm_caches, shared_cache = xs

            def layer_body(x, ys):
                lp, cache = ys
                x, nc = blk.block_decode(lp, cfg, x, None, "ssm", cache,
                                         position)
                return x, nc

            x, new_ssm = jax.lax.scan(layer_body, x, (gparams, ssm_caches))
            x, new_shared = blk.block_decode(shared, cfg, x, None, "dense",
                                             shared_cache, position)
            return x, (new_ssm, new_shared)

        x, (new_ssm, new_shared) = jax.lax.scan(
            group_body, x, (params["blocks"], state["ssm"],
                            state["shared"]))
        state = {"ssm": new_ssm, "shared": new_shared}
    else:
        def body(x, xs):
            lp, cache = xs
            x, nc = blk.block_decode(lp, cfg, x, None, kind, cache, position)
            return x, nc

        x, new_caches = jax.lax.scan(body, x,
                                     (params["blocks"], state["layers"]))
        state = {"layers": new_caches}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], state


def prefill(params, cfg: ModelConfig, batch: dict):
    """Full-sequence forward returning last-position logits (the prefill
    serving step lowered for `prefill_32k`)."""
    logits, _ = forward(params, cfg, batch)
    return logits[:, -1:]


def prefill_with_state(params, cfg: ModelConfig, batch: dict,
                       cache_len: int):
    """One full-sequence pass that ALSO builds the decode caches — the
    production prefill path (vs replaying tokens through decode_step).
    Decoder-only architectures; enc-dec uses the engine's cross-memory
    fill. Returns (last-position logits, serve state)."""
    assert not cfg.is_encdec, "enc-dec prefill handled by the engine"
    x, positions, offset = _embed_inputs(params, cfg, batch)

    if cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, gparams):
            def layer_body(x, lp):
                y, _, cache = blk.block_forward(lp, cfg, x, positions,
                                                "ssm", cache_len=cache_len)
                return y, cache

            x, ssm_caches = jax.lax.scan(layer_body, x, gparams)
            x, _, shared_cache = blk.block_forward(
                shared, cfg, x, positions, "dense", cache_len=cache_len)
            return x, (ssm_caches, shared_cache)

        x, (ssm_caches, shared_caches) = jax.lax.scan(
            group_body, x, params["blocks"])
        state = {"ssm": ssm_caches, "shared": shared_caches}
    else:
        kind = layer_kind(cfg)

        def layer_body(x, lp):
            y, _, cache = blk.block_forward(lp, cfg, x, positions, kind,
                                            cache_len=cache_len)
            return y, cache

        x, caches = jax.lax.scan(layer_body, x, params["blocks"])
        state = {"layers": caches}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, -1:]
    return logits, state
