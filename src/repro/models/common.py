"""Shared model components: the unified ModelConfig, norms, RoPE, embeddings.

One config dataclass covers all ten assigned architectures (dense GQA, MLA,
MoE, SSM, hybrid, enc-dec, VLM/audio backbones); per-arch files in
`repro/configs/` instantiate it with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads

    # --- attention variant -------------------------------------------------
    attn_kind: str = "gqa"    # gqa | mla | none (pure SSM)
    qk_norm: bool = False     # qwen3
    sliding_window: int = 0   # 0 = full attention; >0 = SWA window (mixtral)

    # --- MLA (deepseek-v2 / minicpm3) --------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0      # 0 = direct q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 512        # GShard grouped-dispatch group length
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (zamba2: shared attention block every k layers) --------------
    shared_attn_every: int = 0

    # --- enc-dec (seamless-m4t) ----------------------------------------------
    encoder_layers: int = 0

    # --- multimodal stubs ------------------------------------------------------
    prefix_len: int = 0        # vlm: number of (precomputed) patch embeddings

    # --- misc ------------------------------------------------------------------
    # --- distribution hints (hillclimb levers; see EXPERIMENTS.md §Perf) ----
    seq_parallel: bool = False        # shard the residual stream's seq dim
    act_batch_axes: tuple = ("data",)  # mesh axes carrying the batch dim
    act_model_axis: str = "model"
    # pad Q heads to a multiple of this so they shard over the model axis
    # (14/40-head archs otherwise replicate attention 16x). Padded heads'
    # wo rows are zero-initialized -> outputs and gradients are EXACT.
    tp_head_pad: int = 0

    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32
    vocab_pad_multiple: int = 128
    attn_block_q: int = 1024   # blockwise-attention tile sizes (jnp path)
    attn_block_k: int = 1024
    remat: bool = True
    source: str = ""           # paper / model-card citation

    # ---------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_heads(self) -> int:
        """Physical Q-head count (>= num_heads; multiple of tp_head_pad).
        For GQA, kept a multiple of num_kv_heads so grouping stays exact."""
        if not self.tp_head_pad:
            return self.num_heads
        m = self.tp_head_pad
        h = ((self.num_heads + m - 1) // m) * m
        if self.attn_kind == "gqa" and self.num_kv_heads:
            while h % self.num_kv_heads:
                h += m
        return h

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """The smoke-test variant required by the assignment: <=2 layers,
        d_model<=512, <=4 experts — same family, CPU-runnable."""
        heads = min(self.num_heads, 4) or 4
        kv = min(self.num_kv_heads, heads) if self.num_kv_heads else heads
        d_model = min(self.d_model, 256)
        kw = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=max(1, kv if heads % max(kv, 1) == 0 else heads),
            head_dim=64,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 1024),
            moe_group_size=64,
            attn_block_q=64,
            attn_block_k=64,
            dtype=jnp.float32,
        )
        if self.is_moe:
            kw.update(num_experts=4, top_k=min(self.top_k, 2),
                      num_shared_experts=min(self.num_shared_experts, 1))
        if self.kv_lora_rank:
            kw.update(kv_lora_rank=64, q_lora_rank=0, qk_nope_dim=32,
                      qk_rope_dim=16, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 32), ssm_head_dim=32,
                      ssm_chunk=32)
        if self.shared_attn_every:
            kw.update(shared_attn_every=2)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.prefix_len:
            kw.update(prefix_len=8)
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.with_overrides(**kw)


# ---------------------------------------------------------------------------
# Primitive layers (pure functions over param pytrees)
# ---------------------------------------------------------------------------

def shard_activations(cfg: "ModelConfig", x: jax.Array) -> jax.Array:
    """Sequence-parallel residual stream: constrain (B, S, d) activations to
    shard S over the model axis (batch over the batch axes). Between the TP
    regions XLA then lowers reduce-scatter + all-gather pairs instead of
    full all-reduces, and all elementwise/norm work runs on 1/|model| of the
    tokens. Requires an active mesh (jax.set_mesh) at trace time."""
    if not cfg.seq_parallel or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    ba = cfg.act_batch_axes if len(cfg.act_batch_axes) > 1 \
        else cfg.act_batch_axes[0]
    return jax.lax.with_sharding_constraint(
        x, P(ba, cfg.act_model_axis, None))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float, positions: jax.Array,
                     dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for the given absolute positions: (..., head_dim/2)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) -> (x1 cos - x2 sin, x1 sin + x2 cos).

    x: (..., S, H, D); cos/sin: (S, D/2) broadcast over batch and heads.
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(x.dtype)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


# ---------------------------------------------------------------------------
# Parameter initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: tuple[int, ...], dtype,
               fan_in: int | None = None) -> jax.Array:
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def stacked(keys: jax.Array, fn):
    """vmap an init function over a leading layer axis."""
    return jax.vmap(fn)(keys)
