"""Attention variants: GQA (opt. qk-norm, sliding window) and MLA
(DeepSeek-V2-style multi-head latent attention), with

  * a blockwise online-softmax implementation (the memory-correct jnp path
    used for training and 32k prefill — mirrors the Pallas flash kernel),
  * single-token decode against a (rolling) KV cache, with the *absorbed*
    MLA decode that scores directly in the compressed latent space.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, apply_rope, dense_init,
                                 rms_norm, rope_frequencies)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise attention (shared by GQA and expanded-MLA paths)
# ---------------------------------------------------------------------------

def blockwise_attention(
    q: jax.Array,            # (B, Sq, H, Dh)
    k: jax.Array,            # (B, Sk, KV, Dh)
    v: jax.Array,            # (B, Sk, KV, Dv)
    positions_q: jax.Array,  # (Sq,) absolute positions
    positions_k: jax.Array,  # (Sk,)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention, O(block_q * block_k) live score memory.

    Grouped-query: H = KV * rep; scores computed in grouped layout so KV
    blocks are never materialized at H width.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, KV, Dv = v.shape
    rep = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        positions_q = jnp.pad(positions_q, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        # padded keys get position +inf-ish so causal masking removes them
        positions_k = jnp.pad(positions_k, (0, pad_k),
                              constant_values=jnp.iinfo(jnp.int32).max)
    nq, nk = (Sq + pad_q) // bq, (Sk + pad_k) // bk

    qg = q.reshape(B, nq * bq, KV, rep, Dh)

    def one_q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * bq, bq, axis=1)
        pq = jax.lax.dynamic_slice_in_dim(positions_q, qi * bq, bq, axis=0)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=1)
            pk = jax.lax.dynamic_slice_in_dim(positions_k, ki * bk, bk, axis=0)

            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            # padded keys carry the int32-max sentinel position
            valid = pk[None, :] != jnp.iinfo(jnp.int32).max
            if causal:
                valid &= pk[None, :] <= pq[:, None]
            if window:
                valid &= pk[None, :] > pq[:, None] - window
            valid &= (pq[:, None] >= 0)
            s = jnp.where(valid[None, None, None], s, NEG_INF)

            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, rep, bq, Dv), jnp.float32)
        m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, rep, bq, Dv) -> (B, bq, H, Dv)
        return jnp.moveaxis(out, 3, 1).reshape(B, bq, H, Dv)

    blocks = jax.lax.map(one_q_block, jnp.arange(nq))   # (nq, B, bq, H, Dv)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, nq * bq, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,          # (B, 1, H, Dh)
    k_cache: jax.Array,    # (B, C, KV, Dh)
    v_cache: jax.Array,    # (B, C, KV, Dv)
    slot_positions: jax.Array,  # (C,) absolute position stored per slot, -1 empty
    position: jax.Array,   # scalar current decode position
    window: int = 0,
) -> jax.Array:
    """One-token attention against a (possibly rolling) cache."""
    B, _, H, Dh = q.shape
    KV = k_cache.shape[2]
    rep = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    qg = q.reshape(B, KV, rep, Dh)
    s = jnp.einsum("bgrd,bcgd->bgrc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (slot_positions >= 0) & (slot_positions <= position)
    if window:
        valid &= slot_positions > position - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrc,bcgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array              # (B, C, KV, Dh)
    v: jax.Array              # (B, C, KV, Dv)
    slot_positions: jax.Array  # (C,) int32, -1 = empty


def _zero_pad_heads(w: jax.Array, logical: int, axis: int) -> jax.Array:
    """Zero the padded-head rows so extra heads are exact no-ops."""
    idx = jnp.arange(w.shape[axis]) < logical
    shape = [1] * w.ndim
    shape[axis] = w.shape[axis]
    return w * idx.reshape(shape).astype(w.dtype)


def init_gqa_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, KV, Dh = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    H = cfg.padded_heads
    ks = jax.random.split(key, 4)
    wo = dense_init(ks[3], (H, Dh, d), cfg.dtype, fan_in=H * Dh)
    if H != cfg.num_heads:
        wo = _zero_pad_heads(wo, cfg.num_heads, axis=0)
    p = {
        "wq": dense_init(ks[0], (d, H, Dh), cfg.dtype),
        "wk": dense_init(ks[1], (d, KV, Dh), cfg.dtype),
        "wv": dense_init(ks[2], (d, KV, Dh), cfg.dtype),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), cfg.dtype)
        p["k_norm"] = jnp.ones((Dh,), cfg.dtype)
    return p


def _gqa_project_qkv(params, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dge->bsge", x, params["wk"])
    v = jnp.einsum("bsd,dge->bsge", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_frequencies(cfg.resolved_head_dim, cfg.rope_theta,
                                positions, q.dtype)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _build_kv_cache(k, v, positions, cache_len: int) -> KVCache:
    """Pack computed k/v into a (rolling) cache keeping the last
    `cache_len` tokens."""
    B, S = k.shape[:2]
    C = cache_len
    keep = min(S, C)
    kc = jnp.zeros((B, C, *k.shape[2:]), k.dtype)
    vc = jnp.zeros((B, C, *v.shape[2:]), v.dtype)
    pos_keep = positions[-keep:]
    slots = pos_keep % C
    kc = kc.at[:, slots].set(k[:, -keep:])
    vc = vc.at[:, slots].set(v[:, -keep:])
    sp = jnp.full((C,), -1, jnp.int32).at[slots].set(pos_keep)
    return KVCache(kc, vc, sp)


def gqa_forward(params, cfg: ModelConfig, x, positions, *,
                causal: bool = True, window: int | None = None,
                cache_len: int | None = None):
    """Training / prefill attention. x: (B,S,d); positions: (S,).
    With cache_len, also returns the KV cache for subsequent decode."""
    w = cfg.sliding_window if window is None else window
    q, k, v = _gqa_project_qkv(params, cfg, x, positions)
    out = blockwise_attention(q, k, v, positions, positions, causal=causal,
                              window=w, block_q=cfg.attn_block_q,
                              block_k=cfg.attn_block_k)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    if cache_len is None:
        return y
    return y, _build_kv_cache(k, v, positions, cache_len)


def gqa_prefill_cache(params, cfg: ModelConfig, x, positions,
                      cache_len: int) -> KVCache:
    """Build the cache for decode after a prefill pass (keeps last
    `cache_len` tokens — rolling for SWA)."""
    _, k, v = _gqa_project_qkv(params, cfg, x, positions)
    B, S = x.shape[:2]
    C = cache_len
    keep = min(S, C)
    kc = jnp.zeros((B, C, *k.shape[2:]), k.dtype)
    vc = jnp.zeros((B, C, *v.shape[2:]), v.dtype)
    pos_keep = positions[-keep:]
    slots = pos_keep % C
    kc = kc.at[:, slots].set(k[:, -keep:])
    vc = vc.at[:, slots].set(v[:, -keep:])
    sp = jnp.full((C,), -1, jnp.int32).at[slots].set(pos_keep)
    return KVCache(kc, vc, sp)


def gqa_decode(params, cfg: ModelConfig, x, cache: KVCache,
               position: jax.Array):
    """One-token decode. x: (B,1,d). Returns (out (B,1,d), new cache)."""
    q, k, v = _gqa_project_qkv(params, cfg, x, position[None])
    C = cache.k.shape[1]
    slot = position % C
    kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    sp = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_positions, position[None].astype(jnp.int32), slot, axis=0)
    out = decode_attention(q, kc, vc, sp, position,
                           window=cfg.sliding_window)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, KVCache(kc, vc, sp)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    ckv: jax.Array             # (B, C, r) compressed latents
    krope: jax.Array           # (B, C, Dr) shared rotary key
    slot_positions: jax.Array  # (C,)


def init_mla_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, H = cfg.d_model, cfg.padded_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 6)
    wo = dense_init(ks[3], (H, dv, d), cfg.dtype, fan_in=H * dv)
    if H != cfg.num_heads:
        wo = _zero_pad_heads(wo, cfg.num_heads, axis=0)
    p = {
        "wkv_a": dense_init(ks[1], (d, r + dr), cfg.dtype),
        "kv_norm": jnp.ones((r,), cfg.dtype),
        "wkv_b": dense_init(ks[2], (r, H, dn + dv), cfg.dtype, fan_in=r),
        "wo": wo,
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora_rank), cfg.dtype)
        p["q_norm_a"] = jnp.ones((cfg.q_lora_rank,), cfg.dtype)
        p["wq_b"] = dense_init(ks[4], (cfg.q_lora_rank, H, dn + dr),
                               cfg.dtype, fan_in=cfg.q_lora_rank)
    else:
        p["wq"] = dense_init(ks[0], (d, H, dn + dr), cfg.dtype)
    return p


def _mla_q(params, cfg: ModelConfig, x, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        qa = rms_norm(x @ params["wq_a"], params["q_norm_a"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", qa, params["wq_b"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_frequencies(dr, cfg.rope_theta, positions, q.dtype)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latents(params, cfg: ModelConfig, x, positions):
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = x @ params["wkv_a"]
    ckv = rms_norm(kv[..., :r], params["kv_norm"], cfg.norm_eps)
    krope = kv[..., r:][:, :, None, :]  # single shared rope "head"
    cos, sin = rope_frequencies(dr, cfg.rope_theta, positions, x.dtype)
    krope = apply_rope(krope, cos, sin)[:, :, 0]
    return ckv, krope


def mla_forward(params, cfg: ModelConfig, x, positions, *,
                causal: bool = True, window: int | None = None,
                cache_len: int | None = None):
    """Training / prefill: expand latents to full k/v, run blockwise attn.
    With cache_len, also returns the latent cache for decode."""
    dn, dv = cfg.qk_nope_dim, cfg.v_head_dim
    w = cfg.sliding_window if window is None else window
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, krope = _mla_latents(params, cfg, x, positions)
    kv = jnp.einsum("bsr,rhe->bshe", ckv, params["wkv_b"])
    k_nope, v = kv[..., :dn], kv[..., dn:]
    H = cfg.padded_heads
    k_rope = jnp.broadcast_to(krope[:, :, None, :],
                              (*krope.shape[:2], H, krope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope], -1)
    out = blockwise_attention(q, k, v, positions, positions, causal=causal,
                              window=w, block_q=cfg.attn_block_q,
                              block_k=cfg.attn_block_k)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    if cache_len is None:
        return y
    C = cache_len
    B, S = x.shape[:2]
    keep = min(S, C)
    cc = jnp.zeros((B, C, ckv.shape[-1]), ckv.dtype)
    kc = jnp.zeros((B, C, krope.shape[-1]), krope.dtype)
    pos_keep = positions[-keep:]
    slots = pos_keep % C
    cc = cc.at[:, slots].set(ckv[:, -keep:])
    kc = kc.at[:, slots].set(krope[:, -keep:])
    sp = jnp.full((C,), -1, jnp.int32).at[slots].set(pos_keep)
    return y, MLACache(cc, kc, sp)


def mla_prefill_cache(params, cfg: ModelConfig, x, positions,
                      cache_len: int) -> MLACache:
    ckv, krope = _mla_latents(params, cfg, x, positions)
    B, S = x.shape[:2]
    C = cache_len
    keep = min(S, C)
    cc = jnp.zeros((B, C, ckv.shape[-1]), ckv.dtype)
    kc = jnp.zeros((B, C, krope.shape[-1]), krope.dtype)
    pos_keep = positions[-keep:]
    slots = pos_keep % C
    cc = cc.at[:, slots].set(ckv[:, -keep:])
    kc = kc.at[:, slots].set(krope[:, -keep:])
    sp = jnp.full((C,), -1, jnp.int32).at[slots].set(pos_keep)
    return MLACache(cc, kc, sp)


def mla_decode(params, cfg: ModelConfig, x, cache: MLACache,
               position: jax.Array):
    """Absorbed decode: scores in the r-dim latent space — the cache stays
    (B, C, r + Dr) instead of (B, C, H, Dh) (MLA's memory advantage)."""
    dn, dv, r = cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(params, cfg, x, position[None])
    ckv, krope = _mla_latents(params, cfg, x, position[None])

    C = cache.ckv.shape[1]
    slot = position % C
    cc = jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv, slot, axis=1)
    kc = jax.lax.dynamic_update_slice_in_dim(cache.krope, krope, slot, axis=1)
    sp = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_positions, position[None].astype(jnp.int32), slot, axis=0)

    wk = params["wkv_b"][..., :dn]     # (r, H, dn)
    wv = params["wkv_b"][..., dn:]     # (r, H, dv)
    # absorb W_k into q: q_lat (B,1,H,r)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, wk)
    s = (jnp.einsum("bshr,bcr->bshc", q_lat, cc,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshe,bce->bshc", q_rope, kc,
                      preferred_element_type=jnp.float32))
    s *= 1.0 / jnp.sqrt(jnp.asarray(dn + cfg.qk_rope_dim, jnp.float32))
    valid = (sp >= 0) & (sp <= position)
    if cfg.sliding_window:
        valid &= sp > position - cfg.sliding_window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bshc,bcr->bshr", p.astype(cc.dtype), cc)  # latent ctx
    out_h = jnp.einsum("bshr,rhe->bshe", ctx, wv)               # (B,1,H,dv)
    out = jnp.einsum("bshe,hed->bsd", out_h, params["wo"])
    return out, MLACache(cc, kc, sp)
