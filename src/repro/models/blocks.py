"""Residual blocks: dense attn+MLP, MoE, Mamba2, cross-attention (enc-dec).

Every block is a pure function over a param dict; stacks are built by vmap'd
init and executed under `lax.scan` (one compiled layer body regardless of
depth — essential for the 126-layer dry-runs on a single-core host).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ModelConfig, dense_init, rms_norm,
                                 shard_activations, swiglu)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp_params(cfg: ModelConfig, key: jax.Array, d_ff: int | None = None
                    ) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), cfg.dtype),
        "w_up": dense_init(ks[1], (d, f), cfg.dtype),
        "w_down": dense_init(ks[2], (f, d), cfg.dtype, fan_in=f),
    }


def mlp_forward(params: dict, x: jax.Array) -> jax.Array:
    return swiglu(x @ params["w_gate"], x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# Attention dispatch (GQA vs MLA)
# ---------------------------------------------------------------------------

def init_attn_params(cfg: ModelConfig, key: jax.Array) -> dict:
    if cfg.attn_kind == "mla":
        return attn.init_mla_params(cfg, key)
    return attn.init_gqa_params(cfg, key)


def attn_forward(params, cfg: ModelConfig, x, positions, *, causal=True,
                 window=None, cache_len=None):
    if cfg.attn_kind == "mla":
        return attn.mla_forward(params, cfg, x, positions, causal=causal,
                                window=window, cache_len=cache_len)
    return attn.gqa_forward(params, cfg, x, positions, causal=causal,
                            window=window, cache_len=cache_len)


def attn_decode(params, cfg: ModelConfig, x, cache, position):
    if cfg.attn_kind == "mla":
        return attn.mla_decode(params, cfg, x, cache, position)
    return attn.gqa_decode(params, cfg, x, cache, position)


def attn_empty_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    Dh = cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        return attn.MLACache(
            ckv=jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            krope=jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
            slot_positions=jnp.full((cache_len,), -1, jnp.int32))
    return attn.KVCache(
        k=jnp.zeros((batch, cache_len, cfg.num_kv_heads, Dh), dtype),
        v=jnp.zeros((batch, cache_len, cfg.num_kv_heads, Dh), dtype),
        slot_positions=jnp.full((cache_len,), -1, jnp.int32))


# ---------------------------------------------------------------------------
# Decoder blocks
# ---------------------------------------------------------------------------

def init_block_params(cfg: ModelConfig, key: jax.Array, kind: str) -> dict:
    """kind: dense | moe | ssm."""
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if kind == "ssm":
        return {"ln1": jnp.ones((d,), cfg.dtype),
                "ssm": ssm_mod.init_ssm_params(cfg, ks[0])}
    p = {
        "ln1": jnp.ones((d,), cfg.dtype),
        "attn": init_attn_params(cfg, ks[0]),
        "ln2": jnp.ones((d,), cfg.dtype),
    }
    if kind == "moe":
        p["moe"] = moe_mod.init_moe_params(cfg, ks[1])
    else:
        p["mlp"] = init_mlp_params(cfg, ks[1])
    return p


def block_forward(params: dict, cfg: ModelConfig, x, positions, kind: str,
                  *, causal=True, window=None, cache_len=None):
    """Pre-norm residual block. Returns (x, aux_loss[, cache])."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        x = shard_activations(cfg, x)
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        if cache_len is not None:
            y, cache = ssm_mod.ssm_forward(params["ssm"], cfg, h,
                                           return_cache=True)
            return x + y, aux, cache
        return x + ssm_mod.ssm_forward(params["ssm"], cfg, h), aux
    x = shard_activations(cfg, x)
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    cache = None
    if cache_len is not None:
        y, cache = attn_forward(params["attn"], cfg, h, positions,
                                causal=causal, window=window,
                                cache_len=cache_len)
        x = x + y
    else:
        x = x + attn_forward(params["attn"], cfg, h, positions,
                             causal=causal, window=window)
    x = shard_activations(cfg, x)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_mod.moe_forward(params["moe"], cfg, h)
        x = x + y
    else:
        x = x + mlp_forward(params["mlp"], h)
    if cache_len is not None:
        return x, aux, cache
    return x, aux


def block_decode(params: dict, cfg: ModelConfig, x, positions_unused,
                 kind: str, cache, position):
    """Single-token decode through one block. Returns (x, new_cache)."""
    if kind == "ssm":
        y, new_cache = ssm_mod.ssm_decode(
            params["ssm"], cfg, rms_norm(x, params["ln1"], cfg.norm_eps),
            cache)
        return x + y, new_cache
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    y, new_cache = attn_decode(params["attn"], cfg, h, cache, position)
    x = x + y
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if kind == "moe":
        y, _ = moe_mod.moe_forward(params["moe"], cfg, h)
        x = x + y
    else:
        x = x + mlp_forward(params["mlp"], h)
    return x, new_cache


def block_empty_cache(cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int, dtype):
    if kind == "ssm":
        return ssm_mod.SSMCache(
            conv_x=jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                             dtype),
            conv_bc=jnp.zeros((batch, cfg.ssm_conv_width - 1,
                               2 * cfg.ssm_state), dtype),
            state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32))
    return attn_empty_cache(cfg, batch, cache_len, dtype)


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder blocks)
# ---------------------------------------------------------------------------

def init_cross_block_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((d,), cfg.dtype),
        "self_attn": attn.init_gqa_params(cfg, ks[0]),
        "ln_x": jnp.ones((d,), cfg.dtype),
        "cross_attn": attn.init_gqa_params(cfg, ks[1]),
        "ln2": jnp.ones((d,), cfg.dtype),
        "mlp": init_mlp_params(cfg, ks[2]),
    }


def cross_attend(params, cfg: ModelConfig, x, memory_k, memory_v,
                 positions_q):
    """Query from x, keys/values precomputed from encoder memory (no rope)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    S_enc = memory_k.shape[1]
    pos_k = jnp.arange(S_enc)
    out = attn.blockwise_attention(
        q, memory_k, memory_v, positions_q, pos_k, causal=False, window=0,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def cross_memory_kv(params, memory):
    """Project encoder output into cross-attention k/v once."""
    k = jnp.einsum("bsd,dge->bsge", memory, params["wk"])
    v = jnp.einsum("bsd,dge->bsge", memory, params["wv"])
    return k, v


def cross_block_forward(params, cfg: ModelConfig, x, positions,
                        memory_k, memory_v):
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    x = x + attn.gqa_forward(params["self_attn"], cfg, h, positions,
                             causal=True, window=0)
    h = rms_norm(x, params["ln_x"], cfg.norm_eps)
    x = x + cross_attend(params["cross_attn"], cfg, h, memory_k, memory_v,
                         positions)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    return x + mlp_forward(params["mlp"], h), jnp.zeros((), jnp.float32)


def cross_block_decode(params, cfg: ModelConfig, x, cache, position,
                       memory_k, memory_v):
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    y, new_cache = attn.gqa_decode(params["self_attn"], cfg, h, cache,
                                   position)
    x = x + y
    h = rms_norm(x, params["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, params["cross_attn"]["wq"])
    S_enc = memory_k.shape[1]
    out = attn.decode_attention(q, memory_k, memory_v,
                                jnp.arange(S_enc, dtype=jnp.int32),
                                jnp.asarray(S_enc, jnp.int32), window=0)
    x = x + jnp.einsum("bshe,hed->bsd", out, params["cross_attn"]["wo"])
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    return x + mlp_forward(params["mlp"], h), new_cache
