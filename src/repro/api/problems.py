"""Problem construction: FitConfig/KRRConfig -> the RF-space Problem.

This is the single data path behind `fit(config)` (and, via delegation,
`benchmarks.common.build_problem`): draw the dataset shards, the consensus
graph, the common-seed random features, and assemble the `admm.Problem`
pytree plus the held-out test split.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api.config import FitConfig
from repro.configs.coke_krr import KRRConfig
from repro.core import graph as graph_mod
from repro.core import rff
from repro.core.admm import Problem, make_problem
from repro.data.synthetic import paper_synthetic, uci_standin


@dataclasses.dataclass(frozen=True)
class BuiltProblem:
    problem: Problem
    graph: graph_mod.Graph
    rff_params: rff.RFFParams
    feats_test: jax.Array
    labels_test: jax.Array
    # raw held-out inputs (N, S, d) / (N, S): what `KernelModel.evaluate`
    # consumes — the model owns featurization at inference time
    x_test: jax.Array | None = None
    y_test: jax.Array | None = None


def build_graph(config: FitConfig, num_agents: int,
                seed: int) -> graph_mod.Graph:
    if config.graph == "erdos_renyi":
        return graph_mod.erdos_renyi(num_agents, config.krr.graph_p,
                                     seed=seed)
    if config.graph == "ring":
        return graph_mod.ring(num_agents)
    if config.graph == "circulant":
        return graph_mod.circulant(num_agents, config.graph_offsets)
    if config.graph == "full":
        return graph_mod.fully_connected(num_agents)
    raise ValueError(f"unknown graph family {config.graph!r}")


def build_problem(config: FitConfig | KRRConfig,
                  samples_override: int | None = None) -> BuiltProblem:
    """Construct the decentralized learning problem a config describes.

    Accepts a bare KRRConfig for the legacy ER-graph protocol, or a full
    FitConfig (whose graph family may be ring/circulant for the SPMD
    backends).
    """
    if isinstance(config, KRRConfig):
        config = FitConfig(krr=config)
    cfg = config.krr
    n = samples_override or cfg.samples_per_agent
    if cfg.dataset == "synthetic":
        ds = paper_synthetic(num_agents=cfg.num_agents, samples_per_agent=n,
                             seed=cfg.seed)
        g = build_graph(config, cfg.num_agents, seed=cfg.seed)
    else:
        ds = uci_standin(cfg.dataset, num_agents=cfg.num_agents,
                         subsample=n * cfg.num_agents)
        g = build_graph(config, cfg.num_agents, seed=cfg.seed + 1)
    p = rff.draw_rff(jax.random.PRNGKey(cfg.seed), ds.input_dim,
                     cfg.num_features, cfg.bandwidth, mapping=cfg.mapping)
    feats = rff.featurize(p, jnp.asarray(ds.x))
    labels = jnp.asarray(ds.y)
    prob = make_problem(feats, labels, g, lam=cfg.lam, rho=cfg.rho)
    x_test = jnp.asarray(ds.x_test)
    y_test = jnp.asarray(ds.y_test)
    return BuiltProblem(
        problem=prob, graph=g, rff_params=p,
        feats_test=rff.featurize(p, x_test),
        labels_test=y_test, x_test=x_test, y_test=y_test)
