"""Problem construction: FitConfig/KRRConfig -> the RF-space Problem.

This is the single data path behind `fit(config)` (and, via delegation,
`benchmarks.common.build_problem`): draw the dataset shards, the consensus
graph, the common-seed random features, and assemble the `admm.Problem`
pytree plus the held-out test split.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.configs.coke_krr import KRRConfig
from repro.core import graph as graph_mod
from repro.core import rff
from repro.core.admm import Problem, make_problem
from repro.data.synthetic import (StreamDataset, heterogeneous,
                                  paper_synthetic, stream_synthetic,
                                  uci_standin)


@dataclasses.dataclass(frozen=True)
class BuiltProblem:
    problem: Problem
    graph: graph_mod.Graph
    rff_params: rff.RFFParams
    feats_test: jax.Array
    labels_test: jax.Array
    # raw held-out inputs (N, S, d) / (N, S): what `KernelModel.evaluate`
    # consumes — the model owns featurization at inference time
    x_test: jax.Array | None = None
    y_test: jax.Array | None = None
    # ground-truth latent-task assignment (N,), only for clustered non-IID
    # datasets — what personalize.graph_recovery scores learned graphs
    # against
    clusters: np.ndarray | None = None


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("feats", "labels", "adjacency"),
    meta_fields=("lam", "rho"),
)
@dataclasses.dataclass(frozen=True)
class StreamProblem:
    """The decentralized *online* learning problem: round k feeds agent n
    the fresh, already-featurized minibatch (feats[k, n], labels[k, n]).
    A pytree (array leaves, static lam/rho), so the whole stream traces
    through the fit scan and is sliced per round by the solver."""

    feats: jax.Array   # (R, N, b, D) RF-mapped minibatch streams
    labels: jax.Array  # (R, N, b)
    adjacency: jax.Array  # (N, N)
    lam: float         # global ridge lambda (split lam/N per agent)
    rho: float         # ADMM penalty / step size

    @property
    def num_rounds(self) -> int:
        return self.feats.shape[0]

    @property
    def num_agents(self) -> int:
        return self.feats.shape[1]

    @property
    def batch(self) -> int:
        return self.feats.shape[2]

    @property
    def feature_dim(self) -> int:
        return self.feats.shape[-1]

    def round_batch(self, k) -> tuple[jax.Array, jax.Array]:
        """(feats, labels) of round k (traced-friendly, wraps modulo R)."""
        r = k % self.num_rounds
        return jnp.take(self.feats, r, axis=0), jnp.take(self.labels, r,
                                                         axis=0)


@dataclasses.dataclass(frozen=True)
class BuiltStream:
    stream: StreamProblem
    graph: graph_mod.Graph
    rff_params: rff.RFFParams
    dataset: StreamDataset


def stream_from_arrays(rff_params: rff.RFFParams, x, y,
                       graph_or_adjacency, *, lam: float,
                       rho: float) -> StreamProblem:
    """Featurize a raw (R, N, b, d) / (R, N, b) stream with an existing RFF
    map — how `KernelModel.partial_fit` turns fresh raw traffic into the
    StreamProblem its thetas were trained against."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if x.ndim != 4 or y.ndim != 3 or x.shape[:3] != y.shape:
        raise ValueError(
            "a raw stream is x (R, N, b, d) with labels y (R, N, b); got "
            f"x {x.shape} / y {y.shape}")
    adj = (graph_or_adjacency.adjacency
           if isinstance(graph_or_adjacency, graph_mod.Graph)
           else graph_or_adjacency)
    feats = rff.featurize(rff_params, x)
    return StreamProblem(feats=feats, labels=y,
                         adjacency=jnp.asarray(adj, feats.dtype),
                         lam=lam, rho=rho)


def build_stream(config: FitConfig,
                 num_rounds: int | None = None) -> BuiltStream:
    """Construct the streaming problem a config describes: the per-agent
    minibatch stream (`config.stream` kind, `config.online_batch` sized,
    one round per fit iteration unless `num_rounds` overrides), the
    consensus graph, and the common-seed RFF featurization."""
    cfg = config.krr
    R = config.resolved_iters if num_rounds is None else num_rounds
    if R < 1:
        raise ValueError(f"a stream needs >= 1 round, got {R}")
    ds = stream_synthetic(kind=config.stream, num_rounds=R,
                          num_agents=cfg.num_agents,
                          batch=config.online_batch,
                          bandwidth=cfg.bandwidth, seed=cfg.seed)
    g = build_graph(config, cfg.num_agents, seed=cfg.seed)
    p = rff.draw_rff(jax.random.PRNGKey(cfg.seed), ds.input_dim,
                     cfg.num_features, cfg.bandwidth, mapping=cfg.mapping)
    stream = stream_from_arrays(p, np.asarray(ds.x), np.asarray(ds.y), g,
                                lam=cfg.lam, rho=cfg.rho)
    return BuiltStream(stream=stream, graph=g, rff_params=p, dataset=ds)


def build_graph(config: FitConfig, num_agents: int,
                seed: int) -> graph_mod.Graph:
    if config.graph == "erdos_renyi":
        return graph_mod.erdos_renyi(num_agents, config.krr.graph_p,
                                     seed=seed)
    if config.graph == "ring":
        return graph_mod.ring(num_agents)
    if config.graph == "circulant":
        return graph_mod.circulant(num_agents, config.graph_offsets)
    if config.graph == "full":
        return graph_mod.fully_connected(num_agents)
    raise ValueError(f"unknown graph family {config.graph!r}")


def build_problem(config: FitConfig | KRRConfig,
                  samples_override: int | None = None) -> BuiltProblem:
    """Construct the decentralized learning problem a config describes.

    Accepts a bare KRRConfig for the legacy ER-graph protocol, or a full
    FitConfig (whose graph family may be ring/circulant for the SPMD
    backends).
    """
    if isinstance(config, KRRConfig):
        config = FitConfig(krr=config)
    cfg = config.krr
    n = samples_override or cfg.samples_per_agent
    if cfg.dataset == "synthetic":
        ds = paper_synthetic(num_agents=cfg.num_agents, samples_per_agent=n,
                             seed=cfg.seed)
        g = build_graph(config, cfg.num_agents, seed=cfg.seed)
    elif cfg.dataset == "heterogeneous":
        ds = heterogeneous(num_agents=cfg.num_agents, samples_per_agent=n,
                           num_tasks=cfg.num_tasks, seed=cfg.seed)
        g = build_graph(config, cfg.num_agents, seed=cfg.seed)
    else:
        ds = uci_standin(cfg.dataset, num_agents=cfg.num_agents,
                         subsample=n * cfg.num_agents)
        g = build_graph(config, cfg.num_agents, seed=cfg.seed + 1)
    p = rff.draw_rff(jax.random.PRNGKey(cfg.seed), ds.input_dim,
                     cfg.num_features, cfg.bandwidth, mapping=cfg.mapping)
    feats = rff.featurize(p, jnp.asarray(ds.x))
    labels = jnp.asarray(ds.y)
    prob = make_problem(feats, labels, g, lam=cfg.lam, rho=cfg.rho)
    x_test = jnp.asarray(ds.x_test)
    y_test = jnp.asarray(ds.y_test)
    return BuiltProblem(
        problem=prob, graph=g, rff_params=p,
        feats_test=rff.featurize(p, x_test),
        labels_test=y_test, x_test=x_test, y_test=y_test,
        clusters=getattr(ds, "cluster", None))
