"""`sweep` — vmapped communication-policy grids with per-cell models.

The paper's tuning protocol ("the parameters of the censoring function are
tuned to achieve the best learning performance at nearly no performance
loss") is a grid search over h(k) = v mu^k; QC-ODKLA adds a quantization
axis. Because `fit()` traces every numeric policy knob as array data, a
whole (v, mu, bits, ...) grid is *one* program: `sweep` vmaps the simulator
fit loop over a stacked policy pytree, so 64 policy settings compile once
and run as a single batched scan.

    sw = sweep(FitConfig(algorithm="coke", num_iters=500), grid)
    mses = sw.evaluate(x_test, y_test)["test_mse"]        # (G,)
    idx, model = sw.select(x_test, y_test)                # operating point

Grid cells may be (v, mu) pairs, (v, mu, bits) triples, or explicit
`core.comm` policies (Chain / stage / stage sequence) — all cells must
share one policy structure (that is what makes the grid one compiled
program). `SweepResult.models()` exports every cell as a `KernelModel`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from numbers import Number
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.capabilities import check_sweep
from repro.api.config import FitConfig, SolveContext
from repro.api.fit import _pz_enter_live, phase_plan
from repro.api.model import KernelModel
from repro.api.problems import build_problem
from repro.api.registry import Solver, get_solver
from repro.core import comm as comm_mod
from repro.core.admm import Problem


@partial(jax.jit, static_argnames=("solver", "lengths"))
def _sweep_scan(solver: Solver, problem: Problem, ctxs, host_aux,
                policies, lengths: tuple[int, ...]):
    """One vmapped program over policy cells, phase-aware: each cell runs
    the phases back to back inside its lane (for a personalized sweep:
    the bit-exact warmup program, the carry handoff that attaches the
    starting adjacency, then the live learned-graph program), so a whole
    grid of phased fits is still ONE compiled scan. lengths is static —
    phases are separate traces stitched in sequence; ctxs ride along as
    traced data like the single ctx did."""
    def run_one(chain):
        state, hists = None, []
        for i, (ctx, n) in enumerate(zip(ctxs, lengths)):
            c = dataclasses.replace(ctx, comm=chain)
            aux = solver.prepare_traced(problem, c, host_aux)
            if state is None:
                state = solver.init_state(problem, c)
            elif i > 0:   # the warmup -> live boundary of phase_plan
                state = _pz_enter_live(state, problem.adjacency)

            def body(state, _):
                state = solver.step(problem, c, aux, state)
                return state, solver.metrics(problem, c, aux, state)

            state, h = jax.lax.scan(body, state, None, length=n)
            hists.append(h)
        if len(hists) == 1:
            return state, hists[0]
        return state, jax.tree.map(lambda *xs: jnp.concatenate(xs), *hists)

    return jax.vmap(run_one)(policies)


def _cell_to_policy(cell) -> comm_mod.Chain:
    """One grid cell -> a Chain. (v, mu) pairs and (v, mu, bits) triples
    are shorthand for Censor / Censor+Quantize chains."""
    if isinstance(cell, (comm_mod.Chain, *comm_mod.STAGE_TYPES)):
        return comm_mod.as_chain(cell)
    if isinstance(cell, (tuple, list)):
        cell = tuple(cell)
        if cell and all(isinstance(x, Number) for x in cell):
            if len(cell) == 2:
                v, mu = cell
                return comm_mod.Chain((comm_mod.Censor(float(v),
                                                       float(mu)),))
            if len(cell) == 3:
                v, mu, bits = cell
                return comm_mod.Chain((comm_mod.Censor(float(v), float(mu)),
                                       comm_mod.Quantize(float(bits))))
            raise ValueError(
                f"numeric grid cells must be (v, mu) or (v, mu, bits), "
                f"got {cell!r}")
        return comm_mod.as_chain(cell)  # a sequence of stages
    try:
        return comm_mod.as_chain(cell)  # CensorSchedule, None, ...
    except TypeError:
        raise ValueError(
            f"not a sweepable policy cell: {cell!r}") from None


def _stack_policies(policies: Sequence[comm_mod.Chain]):
    """Stack same-structure chains leaf-wise into one vmappable pytree."""
    structures = {jax.tree.structure(p) for p in policies}
    if len(structures) != 1:
        raise ValueError(
            "all sweep cells must share one policy structure (same stages "
            f"in the same order); got {len(structures)} distinct "
            "structures — mixing e.g. censor-only and censor+quantize "
            "cells would need separate compiled programs")
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *policies)


def _grid_from_configs(configs: Sequence[FitConfig]):
    base = configs[0]
    for c in configs[1:]:
        if c.replace(censor_v=base.censor_v, censor_mu=base.censor_mu,
                     comm=base.comm) != base:
            raise ValueError(
                "sweep over a config list requires the configs to differ "
                "only in their communication policy (censor_v/censor_mu/"
                f"comm); differing cell: {c}")
    return base, [c.resolved_comm for c in configs]


def sweep(configs_or_base: FitConfig | Sequence[FitConfig],
          grid: Iterable | None = None, *,
          problem: Problem | None = None) -> "SweepResult":
    """Fit one problem under a grid of communication policies in a single
    vmapped scan.

    configs_or_base — a base `FitConfig` (policies come from `grid`), or a
                      sequence of FitConfigs that differ only in their
                      communication policy.
    grid            — iterable of cells: (v, mu) pairs, (v, mu, bits)
                      triples, or `core.comm` policies with one shared
                      structure; required with a base config.
    problem         — an existing `admm.Problem`; None builds one from the
                      base config (and the per-cell models inherit its RFF
                      map automatically).
    """
    if isinstance(configs_or_base, FitConfig):
        if grid is None:
            raise ValueError("sweep(base_config) requires a policy grid")
        base = configs_or_base
        cells = [_cell_to_policy(c) for c in grid]
    else:
        if grid is not None:
            raise ValueError("pass either a config list or a base config "
                             "with a grid, not both")
        base, cells = _grid_from_configs(list(configs_or_base))
    if not cells:
        raise ValueError("empty policy grid")
    if base.backend != "simulator":
        raise ValueError(
            "sweep vmaps the in-process simulator loop; run backend="
            f"{base.backend!r} cells individually through fit()")

    solver = get_solver(base.algorithm)
    check_sweep(base, solver)
    rff_params = None
    if problem is None:
        built = build_problem(base)
        problem, rff_params = built.problem, built.rff_params

    # under exec="gossip" each vmapped cell's participation schedule is
    # independent: the draw folds the cell's CommState key, which already
    # folds every (per-cell) numeric policy parameter
    ctx = SolveContext.from_config(base, num_agents=problem.num_agents)
    host_aux = solver.prepare_host(problem, ctx)
    policies = _stack_policies(cells)

    # a personalized sweep replays fit()'s phased program per lane: the
    # plan's (ctx, length) pairs become traced data + static scan lengths
    plan = phase_plan(ctx, base.resolved_iters, problem.adjacency)
    ctxs = tuple(c for c, _, _ in plan)
    lengths = tuple(n for _, n, _ in plan)

    states, history = _sweep_scan(solver, problem, ctxs, host_aux, policies,
                                  lengths=lengths)
    thetas = jax.vmap(solver.theta_of)(states)          # (G, N, D)
    censors = jnp.asarray(
        [FitConfig(krr=base.krr, comm=c).resolved_censor for c in cells],
        jnp.float32)
    return SweepResult(config=base, censors=censors, thetas=thetas,
                       history=history, rff_params=rff_params,
                       policies=tuple(cells))


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """G policy cells fitted on one problem, ready to compare."""

    config: FitConfig
    censors: jax.Array                  # (G, 2): [v, mu] per cell
    thetas: jax.Array                   # (G, N, D) final per-agent params
    history: dict[str, jax.Array]       # each (G, num_iters)
    rff_params: Any = None
    policies: tuple = ()                # (G,) core.comm.Chain per cell

    def __len__(self) -> int:
        return self.thetas.shape[0]

    def cell_config(self, i: int) -> FitConfig:
        if self.policies:
            return self.config.replace(comm=self.policies[i],
                                       censor_v=None, censor_mu=None)
        v, mu = (float(x) for x in self.censors[i])
        return self.config.replace(censor_v=v, censor_mu=mu)

    def model(self, i: int, rff_params=None, *,
              include_per_agent: bool = True) -> KernelModel:
        """Export cell i as a deployable `KernelModel`."""
        from repro.api.config import FitResult

        params = self.rff_params if rff_params is None else rff_params
        res = FitResult(config=self.cell_config(i), state=None,
                        history={k: v[i] for k, v in self.history.items()},
                        theta=self.thetas[i], rff_params=params)
        return res.to_model(include_per_agent=include_per_agent)

    def models(self, rff_params=None, *,
               include_per_agent: bool = True) -> list[KernelModel]:
        """Export every cell as a deployable `KernelModel`."""
        return [self.model(i, rff_params,
                           include_per_agent=include_per_agent)
                for i in range(len(self))]

    def evaluate(self, x: jax.Array, y: jax.Array, *,
                 backend: str = "ref",
                 rff_params=None) -> dict[str, jax.Array]:
        """Per-cell held-out metrics: test_mse (G,), final train_mse (G,),
        final cumulative comms (G,) and bits (G,).

        The test set is featurized ONCE and scored against the stacked
        (G, N, D) thetas — not once per cell (every cell shares the same
        common-seed RFF map)."""
        probe = self.model(0, rff_params)    # carries the shared RFF map
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        phi = probe.featurize(x, backend)
        if x.ndim == 3:
            # per-agent protocol: agent n scores its shard with theta_{g,n}
            preds = jnp.einsum("nsd,gnd->gns", phi, self.thetas)
        else:
            theta_bar = jnp.mean(self.thetas, axis=1)        # (G, D)
            preds = jnp.einsum("sd,gd->gs", phi, theta_bar)
        mses = jnp.mean((y[None] - preds) ** 2,
                        axis=tuple(range(1, preds.ndim)))
        out = {"test_mse": mses,
               "train_mse": self.history["train_mse"][:, -1],
               "comms": self.history["comms"][:, -1]}
        if "bits" in self.history:
            out["bits"] = self.history["bits"][:, -1]
        return out

    def select(self, x: jax.Array, y: jax.Array, *,
               max_mse_gap: float = 0.01,
               rff_params=None) -> tuple[int, KernelModel]:
        """The paper's operating-point rule, extended to the bits axis:
        among cells whose test MSE is within `max_mse_gap` (relative) of
        the best cell, pick the one that paid the fewest cumulative bits;
        ties break on fewest transmissions, then on the lowest cell index
        (deterministic across runs and grid orderings of equal cells).

        Histories without a `bits` trajectory (a policy-unaware solver, or
        externally-built SweepResults) rank on (comms, index) alone — an
        EXPLICIT documented tie-break, never transmission counts dressed
        up in bit units: a comms count is ~D*32 times smaller than the
        bits it stands for, and silently mixing the two units would let a
        bits-reporting cell always lose to a comms-reporting one."""
        ev = self.evaluate(x, y, rff_params=rff_params)
        mses = ev["test_mse"]
        comms = ev["comms"]
        bits = ev.get("bits")
        best = float(jnp.min(mses))
        cutoff = best * (1.0 + max_mse_gap) + 1e-12
        if bits is None:   # no bit accounting: fewest transmissions wins
            candidates = [(float(comms[i]), i)
                          for i in range(len(self))
                          if float(mses[i]) <= cutoff]
        else:
            candidates = [(float(bits[i]), float(comms[i]), i)
                          for i in range(len(self))
                          if float(mses[i]) <= cutoff]
        if not candidates:
            raise ValueError(
                "no sweep cell qualifies for selection — every test MSE is "
                f"non-finite or above the cutoff ({cutoff!r}); the fits "
                "likely diverged (check rho / learning rates): "
                f"test_mse={np.asarray(mses)!r}")
        idx = min(candidates)[-1]
        return idx, self.model(idx, rff_params)
