"""`sweep` — vmapped censor-grid fitting with per-cell deployable models.

The paper's tuning protocol ("the parameters of the censoring function are
tuned to achieve the best learning performance at nearly no performance
loss") is a grid search over h(k) = v mu^k. Because `fit()` traces the
censor thresholds as array data, the whole grid is *one* program: `sweep`
vmaps the simulator fit loop over a (G, 2) threshold array, so 64 censor
settings compile once and run as a single batched scan.

    sw = sweep(FitConfig(algorithm="coke", num_iters=500), grid)
    mses = sw.evaluate(x_test, y_test)["test_mse"]        # (G,)
    idx, model = sw.select(x_test, y_test)                # operating point

`SweepResult.models()` exports every cell as a `KernelModel`, making
"train G censor settings, evaluate all on test data, pick the operating
point" a three-line script.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.api.config import FitConfig, SolveContext
from repro.api.model import KernelModel
from repro.api.problems import build_problem
from repro.api.registry import Solver, get_solver
from repro.core.admm import Problem


@partial(jax.jit, static_argnames=("solver", "num_iters"))
def _sweep_scan(solver: Solver, problem: Problem, ctx: SolveContext,
                host_aux, state0, censors, num_iters: int):
    def run_one(censor):
        c = dataclasses.replace(ctx, censor=censor)
        aux = solver.prepare_traced(problem, c, host_aux)

        def body(state, _):
            state = solver.step(problem, c, aux, state)
            return state, solver.metrics(problem, c, aux, state)

        return jax.lax.scan(body, state0, None, length=num_iters)

    return jax.vmap(run_one)(censors)


def _grid_from_configs(configs: Sequence[FitConfig]):
    base = configs[0]
    for c in configs[1:]:
        if c.replace(censor_v=base.censor_v,
                     censor_mu=base.censor_mu) != base:
            raise ValueError(
                "sweep over a config list requires the configs to differ "
                "only in (censor_v, censor_mu); differing cell: "
                f"{c}")
    return base, [c.resolved_censor for c in configs]


def sweep(configs_or_base: FitConfig | Sequence[FitConfig],
          grid: Iterable[tuple[float, float]] | None = None, *,
          problem: Problem | None = None) -> "SweepResult":
    """Fit one problem under a grid of censor schedules in a single vmapped
    scan.

    configs_or_base — a base `FitConfig` (censor thresholds come from
                      `grid`), or a sequence of FitConfigs that differ only
                      in their censor thresholds.
    grid            — iterable of (v, mu) pairs; required with a base config.
    problem         — an existing `admm.Problem`; None builds one from the
                      base config (and the per-cell models inherit its RFF
                      map automatically).
    """
    if isinstance(configs_or_base, FitConfig):
        if grid is None:
            raise ValueError("sweep(base_config) requires a (v, mu) grid")
        base = configs_or_base
        cells = [(float(v), float(mu)) for v, mu in grid]
    else:
        if grid is not None:
            raise ValueError("pass either a config list or a base config "
                             "with a grid, not both")
        base, cells = _grid_from_configs(list(configs_or_base))
    if not cells:
        raise ValueError("empty censor grid")
    if base.backend != "simulator":
        raise ValueError(
            "sweep vmaps the in-process simulator loop; run backend="
            f"{base.backend!r} cells individually through fit()")

    solver = get_solver(base.algorithm)
    rff_params = None
    if problem is None:
        built = build_problem(base)
        problem, rff_params = built.problem, built.rff_params

    ctx = SolveContext.from_config(base)
    host_aux = solver.prepare_host(problem, ctx)
    state0 = solver.init_state(problem, ctx)
    censors = jnp.asarray(cells, jnp.float32)           # (G, 2)

    states, history = _sweep_scan(solver, problem, ctx, host_aux, state0,
                                  censors, num_iters=base.resolved_iters)
    thetas = jax.vmap(solver.theta_of)(states)          # (G, N, D)
    return SweepResult(config=base, censors=censors, thetas=thetas,
                       history=history, rff_params=rff_params)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """G censor-schedule cells fitted on one problem, ready to compare."""

    config: FitConfig
    censors: jax.Array                  # (G, 2): [v, mu] per cell
    thetas: jax.Array                   # (G, N, D) final per-agent params
    history: dict[str, jax.Array]       # each (G, num_iters)
    rff_params: Any = None

    def __len__(self) -> int:
        return self.censors.shape[0]

    def cell_config(self, i: int) -> FitConfig:
        v, mu = (float(x) for x in self.censors[i])
        return self.config.replace(censor_v=v, censor_mu=mu)

    def model(self, i: int, rff_params=None, *,
              include_per_agent: bool = True) -> KernelModel:
        """Export cell i as a deployable `KernelModel`."""
        from repro.api.config import FitResult

        params = self.rff_params if rff_params is None else rff_params
        res = FitResult(config=self.cell_config(i), state=None,
                        history={k: v[i] for k, v in self.history.items()},
                        theta=self.thetas[i], rff_params=params)
        return res.to_model(include_per_agent=include_per_agent)

    def models(self, rff_params=None, *,
               include_per_agent: bool = True) -> list[KernelModel]:
        """Export every cell as a deployable `KernelModel`."""
        return [self.model(i, rff_params,
                           include_per_agent=include_per_agent)
                for i in range(len(self))]

    def evaluate(self, x: jax.Array, y: jax.Array, *,
                 backend: str = "ref",
                 rff_params=None) -> dict[str, jax.Array]:
        """Per-cell held-out metrics: test_mse (G,), final train_mse (G,),
        final cumulative comms (G,).

        The test set is featurized ONCE and scored against the stacked
        (G, N, D) thetas — not once per cell (every cell shares the same
        common-seed RFF map)."""
        probe = self.model(0, rff_params)    # carries the shared RFF map
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        phi = probe.featurize(x, backend)
        if x.ndim == 3:
            # per-agent protocol: agent n scores its shard with theta_{g,n}
            preds = jnp.einsum("nsd,gnd->gns", phi, self.thetas)
        else:
            theta_bar = jnp.mean(self.thetas, axis=1)        # (G, D)
            preds = jnp.einsum("sd,gd->gs", phi, theta_bar)
        mses = jnp.mean((y[None] - preds) ** 2,
                        axis=tuple(range(1, preds.ndim)))
        return {"test_mse": mses,
                "train_mse": self.history["train_mse"][:, -1],
                "comms": self.history["comms"][:, -1]}

    def select(self, x: jax.Array, y: jax.Array, *,
               max_mse_gap: float = 0.01,
               rff_params=None) -> tuple[int, KernelModel]:
        """The paper's operating-point rule: among cells whose test MSE is
        within `max_mse_gap` (relative) of the best cell, pick the one that
        transmitted least. Returns (cell index, its KernelModel)."""
        ev = self.evaluate(x, y, rff_params=rff_params)
        mses, comms = ev["test_mse"], ev["comms"]
        best = float(jnp.min(mses))
        ok = mses <= best * (1.0 + max_mse_gap) + 1e-12
        comms_masked = jnp.where(ok, comms, jnp.inf)
        idx = int(jnp.argmin(comms_masked))
        return idx, self.model(idx, rff_params)
