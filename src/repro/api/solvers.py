"""Built-in solver adapters: the repo's five algorithm implementations
behind the one `Solver` contract.

Each adapter delegates to the existing math (`core.admm.coke_step`,
`core.cta.cta_step`, `core.online.online_coke_step`, `core.ridge.rf_ridge`)
without changing it — `fit()` reproduces the legacy drivers' trajectories
bit-for-bit (see tests/test_api.py) while giving every algorithm the same
state/metric/backend conventions.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import SolveContext
from repro.api.registry import register_solver
from repro.core import admm, comm as comm_mod, cta, gossip as gossip_mod
from repro.core import online, personalize as personalize_mod, ridge
from repro.core.admm import Problem
from repro.core.graph import Graph, metropolis_weights
from repro.core.personalize import PersonalizedState


def _consensus_gap(theta: jax.Array) -> jax.Array:
    """max_i ||theta_i - mean theta|| over the (N, D) stack — the one
    spelling of the Fig.-1 diagnostic every recorder here uses (the legacy
    `admm.run` arithmetic: bit-parity contract)."""
    mean_theta = jnp.mean(theta, axis=0, keepdims=True)
    return jnp.max(jnp.sqrt(jnp.sum((theta - mean_theta) ** 2, axis=-1)))


def _stacked_metrics(problem: Problem, theta: jax.Array, comms: jax.Array,
                     bits: jax.Array) -> dict[str, jax.Array]:
    """The paper's per-iteration evaluation triple plus cumulative bits,
    the MSE/comms/gap computed exactly as the legacy `admm.run` recorder
    did (bit-parity contract)."""
    preds = jnp.einsum("ntd,nd->nt", problem.feats, theta)
    mse = jnp.mean((problem.labels - preds) ** 2)
    return {"train_mse": mse, "comms": comms,
            "consensus_gap": _consensus_gap(theta),
            "bits": jnp.asarray(bits, jnp.float32)}


def _uncompressed_bits(problem: Problem, comms: jax.Array) -> jax.Array:
    """Bits for `comms` full-precision D-vector transmissions (the policy-
    unaware solvers: CTA broadcasts every iteration, uncompressed)."""
    return comms.astype(jnp.float32) * jnp.float32(
        comm_mod.FP_BITS * problem.feature_dim)


def _per_agent_mse(problem: Problem, theta: jax.Array) -> jax.Array:
    """(N,) per-agent train MSE — the personalized-history metric (mean
    over agents of the consensus `train_mse` only when thetas agree)."""
    preds = jnp.einsum("ntd,nd->nt", problem.feats, theta)
    return jnp.mean((problem.labels - preds) ** 2, axis=-1)


def _pz_live(ctx: SolveContext) -> bool:
    """Is the learned-graph machinery active in THIS compiled program?
    The fit driver splits a personalized run into two programs: the
    warmup phase (ctx.pz_warmup=True) takes the exact static-consensus
    step path — only the per-agent metric readout differs — so the
    pre-refresh prefix is bit-identical to the consensus trajectory by
    construction; the live phase carries the learned adjacency and
    refreshes it on cadence."""
    return ctx.personalization is not None and not ctx.pz_warmup


# ---------------------------------------------------------------------------
# DKLA (Alg. 1) and COKE (Alg. 2): the ADMM family
# ---------------------------------------------------------------------------

class _ADMMSolver:
    backends = ("simulator", "spmd", "fused")
    comm_aware = True
    topology_aware = True
    # these solvers HAVE a (21a) primal subproblem the cholesky/cg exact
    # solves apply to; fit() rejects forcing those modes on solvers without
    # one (cta/online/oracle) instead of silently running something else
    primal_aware = True
    # the ADMM update has a well-defined asynchronous form (sampled
    # participants step, sleepers hold, duals delayed-but-correct) —
    # exec="gossip" admits these solvers (core.gossip.gossip_coke_step)
    gossip_aware = True
    # the consensus penalty rho sum_n ||theta_i - theta_hat_n||^2 accepts
    # a learned weighted graph directly (deg_i becomes sum_j w_ij) —
    # FitConfig.personalization admits these solvers
    personalization_aware = True

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        raise NotImplementedError

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        # gossip execution reads the graph through a padded neighbor-index
        # table (gathers, no dense (N, N) on the hot path) — built once,
        # eagerly, from the host adjacency. The live personalized phase
        # relearns its graph inside the scan, which a host-built static
        # table cannot follow: the dense personalized steps need no aux
        # (the warmup phase runs the static table path).
        if ctx.exec == "gossip" and not _pz_live(ctx):
            return gossip_mod.NeighborTable.from_adjacency(
                np.asarray(problem.adjacency))
        return None

    def _primal_mode(self, problem: Problem, ctx: SolveContext) -> str:
        """The concrete primal update for this (problem, context) pair:
        Cholesky / CG across the big-D crossover, gradient for general
        losses — see core.admm.resolve_primal. Under churn or a learned
        collaboration graph the degrees are time-varying, so "auto" falls
        through to the matrix-free CG solve (an explicit
        primal="cholesky" is rejected up front by the registry checks)."""
        mode = admm.resolve_primal(ctx.primal, problem.feature_dim,
                                   problem.loss)
        if mode == "cholesky" and (
                ctx.personalization is not None
                or (ctx.gossip is not None and ctx.gossip.has_churn)):
            mode = "cg"
        return mode

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        # Cholesky factors inside the compiled loop, exactly where the
        # legacy jitted `admm.run` built them. Under a topology schedule
        # the (18a) normal matrix depends on the per-graph degrees, so a
        # (M, N, D, D) stack is factored and coke_step gathers per k.
        # The cg / gradient primals are matrix-free: no aux at all.
        if _pz_live(ctx):
            return None     # matrix-free primal, graph lives in the state
        if ctx.exec == "gossip":
            chol = None
            if self._primal_mode(problem, ctx) == "cholesky":
                chol = admm._ridge_factors(problem, deg=host_aux.degrees())
            return {"table": host_aux, "chol": chol}
        if self._primal_mode(problem, ctx) != "cholesky":
            return None
        if ctx.topology is None:
            return admm._ridge_factors(problem)
        return jax.vmap(lambda A: admm._ridge_factors(
            dataclasses.replace(problem, adjacency=A)))(
                ctx.topology.adjacencies)

    def init_state(self, problem: Problem, ctx: SolveContext):
        inner = admm.init_state(problem, policy=self._policy(ctx))
        if _pz_live(ctx):
            # the learned graph starts as the configured static one and
            # rides in the carry so refreshes happen inside the scan
            return PersonalizedState(
                inner, jnp.asarray(problem.adjacency, jnp.float32))
        return inner

    def step(self, problem: Problem, ctx: SolveContext, aux, state):
        mode = self._primal_mode(problem, ctx)
        if _pz_live(ctx):
            pz = ctx.personalization
            if ctx.exec == "gossip":
                return personalize_mod.gossip_coke_step_dense(
                    problem, self._policy(ctx), pz, state, ctx.gossip,
                    inner_steps=ctx.inner_steps, inner_lr=ctx.inner_lr,
                    primal="cg" if mode == "cg" else "gradient",
                    cg_tol=ctx.cg_tol, cg_maxiter=ctx.cg_maxiter)
            # sync: refresh the graph if due, then delegate to the
            # unmodified coke_step on it — before the first refresh this
            # is bit-identical to the static-topology run (the
            # prefix-invariance pin)
            A = personalize_mod.maybe_update(
                pz, state.inner.theta, state.inner.step + 1,
                state.adjacency)
            inner = admm.coke_step(
                dataclasses.replace(problem, adjacency=A),
                self._policy(ctx), state.inner, None,
                ctx.inner_steps, ctx.inner_lr,
                primal="cg" if mode == "cg" else "auto",
                cg_tol=ctx.cg_tol, cg_maxiter=ctx.cg_maxiter)
            return PersonalizedState(inner, A)
        if ctx.exec == "gossip":
            return gossip_mod.gossip_coke_step(
                problem, self._policy(ctx), state, aux["table"], ctx.gossip,
                chol=aux["chol"], inner_steps=ctx.inner_steps,
                inner_lr=ctx.inner_lr,
                primal=mode if mode in ("cg", "cholesky") else "gradient",
                cg_tol=ctx.cg_tol, cg_maxiter=ctx.cg_maxiter)
        return admm.coke_step(problem, self._policy(ctx), state, aux,
                              ctx.inner_steps, ctx.inner_lr,
                              topology=ctx.topology,
                              primal="cg" if mode == "cg" else "auto",
                              cg_tol=ctx.cg_tol, cg_maxiter=ctx.cg_maxiter)

    def metrics(self, problem: Problem, ctx: SolveContext, aux, state):
        # both personalized phases emit per_agent_mse (key parity across
        # the warmup/live history concatenation); the warmup-phase state
        # is a bare COKEState
        inner = state.inner if isinstance(state, PersonalizedState) \
            else state
        m = _stacked_metrics(problem, inner.theta, inner.comms,
                             jnp.sum(inner.comm.bits))
        if ctx.personalization is not None:
            m["per_agent_mse"] = _per_agent_mse(problem, inner.theta)
        return m

    def theta_of(self, state) -> jax.Array:
        if isinstance(state, PersonalizedState):
            return state.inner.theta
        return state.theta


@register_solver("dkla")
class DKLASolver(_ADMMSolver):
    """Algorithm 1: COKE's update with the always-transmit h == 0 policy.
    Non-censor stages of the configured policy (quantize, drop) still
    apply — quantized DKLA is the Q-ODKLA ablation."""

    consensus_strategy = "dkla"

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return comm_mod.uncensored(ctx.comm)


@register_solver("coke")
class COKESolver(_ADMMSolver):
    """Algorithm 2: censored transmissions, h(k) = v mu^k with traced v, mu
    (plus any composed quantize/drop stages of the configured policy)."""

    consensus_strategy = "coke"

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return ctx.comm


# ---------------------------------------------------------------------------
# CTA diffusion baseline
# ---------------------------------------------------------------------------

@register_solver("cta")
class CTASolver:
    """Combine-then-adapt diffusion (Section 5 baseline): Metropolis mixing
    then a local gradient step; transmits every iteration."""

    backends = ("simulator", "spmd")
    consensus_strategy = "cta"
    comm_aware = False  # diffusion transmits uncensored every iteration
    topology_aware = False

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        g = Graph(adjacency=np.asarray(problem.adjacency, np.float64))
        return jnp.asarray(metropolis_weights(g), problem.feats.dtype)

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        return host_aux  # the mixing matrix

    def init_state(self, problem: Problem, ctx: SolveContext):
        return cta.init_state(problem)

    def step(self, problem: Problem, ctx: SolveContext, aux, state):
        return cta.cta_step(problem, aux, ctx.cta_lr, state)

    def metrics(self, problem: Problem, ctx: SolveContext, aux, state):
        return _stacked_metrics(problem, state.theta, state.comms,
                                _uncompressed_bits(problem, state.comms))

    def theta_of(self, state) -> jax.Array:
        return state.theta


# ---------------------------------------------------------------------------
# The streaming family: online-DKLA, online-COKE, QC-ODKLA
# ---------------------------------------------------------------------------

class OnlineFitState(NamedTuple):
    inner: online.OnlineState
    inst_mse: jax.Array   # pre-update MSE on the round's incoming minibatch
    # learned collaboration graph, carried only under personalization
    # (None otherwise — a static pytree shape on every other path)
    adjacency: jax.Array | None = None


def _stream_metrics(theta: jax.Array, comms: jax.Array, bits: jax.Array,
                    inst: jax.Array) -> dict[str, jax.Array]:
    """Streaming history: the regret sample (pre-update instantaneous MSE,
    doubling as the train_mse trajectory — a stream has no fixed train
    set), cumulative comms/bits, and the consensus gap. Key-identical on
    every streaming backend (backends._stream_chunk mirrors it)."""
    return {"train_mse": inst, "instant_mse": inst, "comms": comms,
            "consensus_gap": _consensus_gap(theta),
            "bits": jnp.asarray(bits, jnp.float32)}


class _OnlineSolver:
    """Shared adapter for the streaming family. Works on two problem
    forms: a `StreamProblem` (fit_stream — round k is the stream's k-th
    minibatch) and, for backward compatibility, a batch `admm.Problem`
    (fit — round k is a rotating `online_batch`-sized window over each
    agent's local shard). Records the online-protocol regret metric
    (pre-update instantaneous MSE) either way."""

    backends = ("simulator",)              # the batch fit() contract
    stream_backends = ("simulator", "spmd")
    streaming = True
    consensus_strategy = None
    comm_aware = True
    topology_aware = False
    # the streaming round has the same asynchronous form as the ADMM one:
    # sampled participants take the minibatch step and gossip, sleepers
    # hold (core.gossip.gossip_stream_step)
    gossip_aware = True
    # the streaming consensus penalty takes a learned weighted graph the
    # same way the batch one does (deg_i = sum_j w_ij)
    personalization_aware = True

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        raise NotImplementedError

    def _eta(self, ctx: SolveContext) -> float | None:
        """Linearized-ADMM proximal coefficient; None = gradient step."""
        return None

    def prepare_host(self, problem, ctx: SolveContext):
        if ctx.exec == "gossip" and not _pz_live(ctx):
            return gossip_mod.NeighborTable.from_adjacency(
                np.asarray(problem.adjacency))
        return None

    def prepare_traced(self, problem, ctx: SolveContext, host_aux):
        return host_aux  # gossip: the neighbor table; sync: None

    def init_state(self, problem, ctx: SolveContext):
        N, D = problem.num_agents, problem.feature_dim
        inner = online.init_state(N, D, problem.feats.dtype,
                                  policy=self._policy(ctx))
        A = None
        if _pz_live(ctx):
            A = jnp.asarray(problem.adjacency, jnp.float32)
        return OnlineFitState(inner, jnp.zeros((), problem.feats.dtype), A)

    def warm_start(self, state: OnlineFitState, theta0) -> OnlineFitState:
        """Re-seed a fresh state from deployed parameters: theta AND the
        last-broadcast theta_hat start at theta0 (every agent knows the
        deployed model), duals stay zero — KernelModel.partial_fit's
        online-refinement entry."""
        theta0 = jnp.broadcast_to(
            jnp.asarray(theta0, state.inner.theta.dtype),
            state.inner.theta.shape)
        inner = state.inner._replace(theta=theta0, theta_hat=theta0)
        return state._replace(inner=inner)

    def _round_batch(self, problem, ctx: SolveContext, step):
        from repro.api.problems import StreamProblem  # local: avoid cycle

        if isinstance(problem, StreamProblem):
            return problem.round_batch(step)
        b, Ti = ctx.online_batch, problem.feats.shape[1]
        idx = (step * b + jnp.arange(b)) % Ti
        return (jnp.take(problem.feats, idx, axis=1),
                jnp.take(problem.labels, idx, axis=1))

    def step(self, problem, ctx: SolveContext, aux,
             state: OnlineFitState):
        feats, labels = self._round_batch(problem, ctx, state.inner.step)
        if _pz_live(ctx):
            # refresh the learned graph if due, then take the round on it
            A = personalize_mod.maybe_update(
                ctx.personalization, state.inner.theta,
                state.inner.step + 1, state.adjacency)
            if ctx.exec == "gossip":
                inner, inst = personalize_mod.gossip_stream_step_dense(
                    state.inner, feats, labels, A, self._policy(ctx),
                    ctx.gossip, lam=problem.lam, rho=problem.rho,
                    lr=ctx.online_lr, eta=self._eta(ctx))
            else:
                inner, inst = online.stream_step(
                    state.inner, feats, labels, A, self._policy(ctx),
                    lam=problem.lam, rho=problem.rho,
                    lr=ctx.online_lr, eta=self._eta(ctx))
            return OnlineFitState(inner, inst, A)
        if ctx.exec == "gossip":
            inner, inst = gossip_mod.gossip_stream_step(
                state.inner, feats, labels, aux, self._policy(ctx),
                ctx.gossip, lam=problem.lam, rho=problem.rho,
                lr=ctx.online_lr, eta=self._eta(ctx))
        else:
            inner, inst = online.stream_step(
                state.inner, feats, labels, problem.adjacency,
                self._policy(ctx), lam=problem.lam, rho=problem.rho,
                lr=ctx.online_lr, eta=self._eta(ctx))
        return OnlineFitState(inner, inst)

    def metrics(self, problem, ctx: SolveContext, aux,
                state: OnlineFitState):
        from repro.api.problems import StreamProblem  # local: avoid cycle

        if isinstance(problem, StreamProblem):
            # stream histories stay scalar-per-round even under
            # personalization: a stream has no fixed per-agent test set
            # to score, and the regret sample is already per-round
            return _stream_metrics(state.inner.theta, state.inner.comms,
                                   jnp.sum(state.inner.comm.bits),
                                   state.inst_mse)
        m = _stacked_metrics(problem, state.inner.theta, state.inner.comms,
                             jnp.sum(state.inner.comm.bits))
        m["instant_mse"] = state.inst_mse
        if ctx.personalization is not None:
            m["per_agent_mse"] = _per_agent_mse(problem, state.inner.theta)
        return m

    def theta_of(self, state: OnlineFitState) -> jax.Array:
        return state.inner.theta


@register_solver("online_dkla")
class OnlineDKLASolver(_OnlineSolver):
    """Streaming DKLA: the always-transmit baseline of the online family.
    Censor thresholds of the configured policy are structurally stripped
    (like batch DKLA); quantize/drop stages still apply."""

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return comm_mod.uncensored(ctx.comm)


@register_solver("online_coke")
class OnlineCOKESolver(_OnlineSolver):
    """Streaming COKE (the paper's future-work direction): one censored
    gradient step on the streaming augmented Lagrangian per round."""

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return ctx.comm


@register_solver("qc_odkla")
class QCODKLASolver(_OnlineSolver):
    """QC-ODKLA (Xu et al., 2022): linearized-ADMM primal (closed form,
    per-agent stepsize 1/(eta + 2 rho deg_i)) with the full
    Censor/Quantize/Drop policy chain threading through CommState.
    `qc_eta=None` (the default) reuses the gradient stepsize `online_lr`,
    in which case qc_odkla with the identity chain extension is
    bit-identical to online_coke — the contract tests/test_stream.py
    pins."""

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return ctx.comm

    def _eta(self, ctx: SolveContext) -> float | None:
        return ctx.qc_eta


# ---------------------------------------------------------------------------
# Centralized closed-form oracle (Eq. 26)
# ---------------------------------------------------------------------------

class OracleState(NamedTuple):
    theta: jax.Array   # (N, D) — theta* broadcast to every agent
    step: jax.Array
    comms: jax.Array


@register_solver("ridge_oracle")
class RidgeOracleSolver:
    """The centralized RF-ridge optimum the decentralized algorithms must
    converge to, exposed through the same fit surface (run num_iters=1).
    Its `comms` metric is 0: the oracle sees all data, exchanges nothing."""

    backends = ("simulator",)
    consensus_strategy = None
    comm_aware = False  # sees all data, exchanges nothing
    topology_aware = False

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        return None

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        return ridge.rf_ridge(problem.feats, problem.labels, problem.lam)

    def init_state(self, problem: Problem, ctx: SolveContext):
        N, D = problem.num_agents, problem.feature_dim
        return OracleState(jnp.zeros((N, D), problem.feats.dtype),
                           jnp.zeros((), jnp.int32),
                           jnp.zeros((), jnp.int32))

    def step(self, problem: Problem, ctx: SolveContext, aux,
             state: OracleState):
        theta = jnp.broadcast_to(aux[None], state.theta.shape)
        return OracleState(theta.astype(state.theta.dtype),
                           state.step + 1, state.comms)

    def metrics(self, problem: Problem, ctx: SolveContext, aux,
                state: OracleState):
        return _stacked_metrics(problem, state.theta, state.comms,
                                jnp.zeros((), jnp.int32))

    def theta_of(self, state: OracleState) -> jax.Array:
        return state.theta
