"""Built-in solver adapters: the repo's five algorithm implementations
behind the one `Solver` contract.

Each adapter delegates to the existing math (`core.admm.coke_step`,
`core.cta.cta_step`, `core.online.online_coke_step`, `core.ridge.rf_ridge`)
without changing it — `fit()` reproduces the legacy drivers' trajectories
bit-for-bit (see tests/test_api.py) while giving every algorithm the same
state/metric/backend conventions.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import SolveContext
from repro.api.registry import register_solver
from repro.core import admm, comm as comm_mod, cta, online, ridge
from repro.core.admm import Problem
from repro.core.graph import Graph, metropolis_weights


def _stacked_metrics(problem: Problem, theta: jax.Array, comms: jax.Array,
                     bits: jax.Array) -> dict[str, jax.Array]:
    """The paper's per-iteration evaluation triple plus cumulative bits,
    the MSE/comms/gap computed exactly as the legacy `admm.run` recorder
    did (bit-parity contract)."""
    preds = jnp.einsum("ntd,nd->nt", problem.feats, theta)
    mse = jnp.mean((problem.labels - preds) ** 2)
    mean_theta = jnp.mean(theta, axis=0, keepdims=True)
    gap = jnp.max(jnp.sqrt(jnp.sum((theta - mean_theta) ** 2, axis=-1)))
    return {"train_mse": mse, "comms": comms, "consensus_gap": gap,
            "bits": jnp.asarray(bits, jnp.float32)}


def _uncompressed_bits(problem: Problem, comms: jax.Array) -> jax.Array:
    """Bits for `comms` full-precision D-vector transmissions (the policy-
    unaware solvers: CTA broadcasts every iteration, uncompressed)."""
    return comms.astype(jnp.float32) * jnp.float32(
        comm_mod.FP_BITS * problem.feature_dim)


# ---------------------------------------------------------------------------
# DKLA (Alg. 1) and COKE (Alg. 2): the ADMM family
# ---------------------------------------------------------------------------

class _ADMMSolver:
    backends = ("simulator", "spmd", "fused")
    comm_aware = True
    topology_aware = True
    # these solvers HAVE a (21a) primal subproblem the cholesky/cg exact
    # solves apply to; fit() rejects forcing those modes on solvers without
    # one (cta/online/oracle) instead of silently running something else
    primal_aware = True

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        raise NotImplementedError

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        return None

    def _primal_mode(self, problem: Problem, ctx: SolveContext) -> str:
        """The concrete primal update for this (problem, context) pair:
        Cholesky / CG across the big-D crossover, gradient for general
        losses — see core.admm.resolve_primal."""
        return admm.resolve_primal(ctx.primal, problem.feature_dim,
                                   problem.loss)

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        # Cholesky factors inside the compiled loop, exactly where the
        # legacy jitted `admm.run` built them. Under a topology schedule
        # the (18a) normal matrix depends on the per-graph degrees, so a
        # (M, N, D, D) stack is factored and coke_step gathers per k.
        # The cg / gradient primals are matrix-free: no aux at all.
        if self._primal_mode(problem, ctx) != "cholesky":
            return None
        if ctx.topology is None:
            return admm._ridge_factors(problem)
        return jax.vmap(lambda A: admm._ridge_factors(
            dataclasses.replace(problem, adjacency=A)))(
                ctx.topology.adjacencies)

    def init_state(self, problem: Problem, ctx: SolveContext):
        return admm.init_state(problem, policy=self._policy(ctx))

    def step(self, problem: Problem, ctx: SolveContext, aux, state):
        mode = self._primal_mode(problem, ctx)
        return admm.coke_step(problem, self._policy(ctx), state, aux,
                              ctx.inner_steps, ctx.inner_lr,
                              topology=ctx.topology,
                              primal="cg" if mode == "cg" else "auto",
                              cg_tol=ctx.cg_tol, cg_maxiter=ctx.cg_maxiter)

    def metrics(self, problem: Problem, ctx: SolveContext, aux, state):
        return _stacked_metrics(problem, state.theta, state.comms,
                                jnp.sum(state.comm.bits))

    def theta_of(self, state) -> jax.Array:
        return state.theta


@register_solver("dkla")
class DKLASolver(_ADMMSolver):
    """Algorithm 1: COKE's update with the always-transmit h == 0 policy.
    Non-censor stages of the configured policy (quantize, drop) still
    apply — quantized DKLA is the Q-ODKLA ablation."""

    consensus_strategy = "dkla"

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return comm_mod.uncensored(ctx.comm)


@register_solver("coke")
class COKESolver(_ADMMSolver):
    """Algorithm 2: censored transmissions, h(k) = v mu^k with traced v, mu
    (plus any composed quantize/drop stages of the configured policy)."""

    consensus_strategy = "coke"

    def _policy(self, ctx: SolveContext) -> comm_mod.Chain:
        return ctx.comm


# ---------------------------------------------------------------------------
# CTA diffusion baseline
# ---------------------------------------------------------------------------

@register_solver("cta")
class CTASolver:
    """Combine-then-adapt diffusion (Section 5 baseline): Metropolis mixing
    then a local gradient step; transmits every iteration."""

    backends = ("simulator", "spmd")
    consensus_strategy = "cta"
    comm_aware = False  # diffusion transmits uncensored every iteration
    topology_aware = False

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        g = Graph(adjacency=np.asarray(problem.adjacency, np.float64))
        return jnp.asarray(metropolis_weights(g), problem.feats.dtype)

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        return host_aux  # the mixing matrix

    def init_state(self, problem: Problem, ctx: SolveContext):
        return cta.init_state(problem)

    def step(self, problem: Problem, ctx: SolveContext, aux, state):
        return cta.cta_step(problem, aux, ctx.cta_lr, state)

    def metrics(self, problem: Problem, ctx: SolveContext, aux, state):
        return _stacked_metrics(problem, state.theta, state.comms,
                                _uncompressed_bits(problem, state.comms))

    def theta_of(self, state) -> jax.Array:
        return state.theta


# ---------------------------------------------------------------------------
# Streaming (online) COKE
# ---------------------------------------------------------------------------

class OnlineFitState(NamedTuple):
    inner: online.OnlineState
    inst_mse: jax.Array   # pre-update MSE on the round's incoming minibatch


@register_solver("online_coke")
class OnlineCOKESolver:
    """Streaming COKE over the problem's local shards: round k feeds each
    agent a rotating `online_batch`-sized window of its own data as the
    fresh minibatch, takes one censored streaming-ADMM step, and records
    the online-protocol regret metric (pre-update instantaneous MSE)."""

    backends = ("simulator",)
    consensus_strategy = None
    comm_aware = True
    topology_aware = False

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        return None

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        return None

    def init_state(self, problem: Problem, ctx: SolveContext):
        N, D = problem.num_agents, problem.feature_dim
        inner = online.init_state(N, D, problem.feats.dtype,
                                  policy=ctx.comm)
        return OnlineFitState(inner, jnp.zeros((), problem.feats.dtype))

    def step(self, problem: Problem, ctx: SolveContext, aux,
             state: OnlineFitState):
        b, Ti = ctx.online_batch, problem.feats.shape[1]
        idx = (state.inner.step * b + jnp.arange(b)) % Ti
        feats = jnp.take(problem.feats, idx, axis=1)
        labels = jnp.take(problem.labels, idx, axis=1)
        inner, inst = online.online_coke_step(
            state.inner, feats, labels, problem.adjacency, ctx.comm,
            lam=problem.lam, rho=problem.rho, lr=ctx.online_lr)
        return OnlineFitState(inner, inst)

    def metrics(self, problem: Problem, ctx: SolveContext, aux,
                state: OnlineFitState):
        m = _stacked_metrics(problem, state.inner.theta, state.inner.comms,
                             jnp.sum(state.inner.comm.bits))
        m["instant_mse"] = state.inst_mse
        return m

    def theta_of(self, state: OnlineFitState) -> jax.Array:
        return state.inner.theta


# ---------------------------------------------------------------------------
# Centralized closed-form oracle (Eq. 26)
# ---------------------------------------------------------------------------

class OracleState(NamedTuple):
    theta: jax.Array   # (N, D) — theta* broadcast to every agent
    step: jax.Array
    comms: jax.Array


@register_solver("ridge_oracle")
class RidgeOracleSolver:
    """The centralized RF-ridge optimum the decentralized algorithms must
    converge to, exposed through the same fit surface (run num_iters=1).
    Its `comms` metric is 0: the oracle sees all data, exchanges nothing."""

    backends = ("simulator",)
    consensus_strategy = None
    comm_aware = False  # sees all data, exchanges nothing
    topology_aware = False

    def prepare_host(self, problem: Problem, ctx: SolveContext):
        return None

    def prepare_traced(self, problem: Problem, ctx: SolveContext, host_aux):
        return ridge.rf_ridge(problem.feats, problem.labels, problem.lam)

    def init_state(self, problem: Problem, ctx: SolveContext):
        N, D = problem.num_agents, problem.feature_dim
        return OracleState(jnp.zeros((N, D), problem.feats.dtype),
                           jnp.zeros((), jnp.int32),
                           jnp.zeros((), jnp.int32))

    def step(self, problem: Problem, ctx: SolveContext, aux,
             state: OracleState):
        theta = jnp.broadcast_to(aux[None], state.theta.shape)
        return OracleState(theta.astype(state.theta.dtype),
                           state.step + 1, state.comms)

    def metrics(self, problem: Problem, ctx: SolveContext, aux,
                state: OracleState):
        return _stacked_metrics(problem, state.theta, state.comms,
                                jnp.zeros((), jnp.int32))

    def theta_of(self, state: OracleState) -> jax.Array:
        return state.theta
