"""`KernelModel` — the deployable artifact that closes the fit→deploy loop.

`fit()` ends at a `FitResult` whose theta is a raw (N, D) array;
`FitResult.to_model()` packages it with the random-feature map that gives it
meaning: the common-seed RFF parameters (omega, bias, mapping), the kernel
family/bandwidth, the consensus-averaged theta (plus the per-agent stack for
the paper's Section-5 test protocol), and the originating `FitConfig`
metadata. The artifact is what the paper's construction promises: because
random features are data-independent, the fitted function is a pair
(RFF map, theta) that *any* node can score with — no training data, graph,
or ADMM state needed at inference time.

    model = fit(config).to_model()
    y_hat = model.predict(x_new)              # chunked, ref or fused backend
    model.evaluate(x_test, y_test)            # the paper's test-MSE metrics
    model.save("artifacts/coke")              # npz + JSON sidecar
    model = KernelModel.load("artifacts/coke")

Scoring backends: "ref" is the eager `repro.core.rff` reference path
(bit-identical to what training recorded); "fused" routes featurization
through the Pallas `kernels/rff` kernel (one VMEM pass for matmul + cosine —
compiled on TPU/GPU, interpret mode on CPU via
`repro.kernels.runtime.resolve_interpret`, `$REPRO_PALLAS_INTERPRET`
overrides). Parity is tested in tests/test_model.py.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import rff
from repro.kernels.rff.ops import featurize_fused

PREDICT_BACKENDS = ("ref", "fused")


@functools.partial(jax.jit, static_argnames=("mapping", "backend"))
def _score_rows_jit(omega, bias, x, thetas, mapping, backend):
    # Jitted on purpose: the multi-tenant KernelServer scores through a
    # jitted gather+einsum, and XLA fuses the featurizer's constant scales
    # differently under jit than eager — so the bit-level reference must
    # live on the same side of that fence.
    params = rff.RFFParams(omega=omega, bias=bias, mapping=mapping)
    if backend == "fused":
        phi = featurize_fused(params, x)
    else:
        phi = rff.featurize(params, x)
    return jnp.einsum("bd,bd->b", phi, thetas)


@dataclasses.dataclass(frozen=True)
class KernelModel:
    """A fitted decentralized-kernel-learning function, ready to deploy.

    rff_params — the common-seed random-feature map (omega (d, L), bias (L,),
                 mapping) every agent trained against.
    theta      — (D,) consensus-averaged parameters: the deployable function
                 f(x) = phi(x)' theta.
    thetas     — optional (N, D) per-agent stack; kept so `evaluate` can
                 reproduce the paper's per-agent test protocol and so the
                 consensus gap remains inspectable post-hoc.
    bandwidth  — Gaussian-kernel bandwidth the spectral samples were drawn
                 for (metadata; omega already encodes it).
    kernel     — kernel family name (only "gaussian" is drawn today).
    meta       — JSON-serializable provenance from the originating FitConfig
                 (algorithm, censor schedule, iterations, dataset, ...).
    model_id   — registry identity (`serve.ModelRegistry` key) this artifact
                 was published under, or None for an unregistered model.
    version    — registry version the artifact was published as; together
                 with model_id this makes every saved artifact say exactly
                 which catalog entry it is.
    """

    rff_params: rff.RFFParams
    theta: jax.Array
    thetas: jax.Array | None = None
    bandwidth: float = 1.0
    kernel: str = "gaussian"
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    model_id: str | None = None
    version: int | None = None

    # ---- shape accessors -------------------------------------------------
    @property
    def input_dim(self) -> int:
        return self.rff_params.input_dim

    @property
    def num_features(self) -> int:
        return self.rff_params.num_features

    @property
    def num_agents(self) -> int | None:
        return None if self.thetas is None else self.thetas.shape[0]

    # ---- placement -------------------------------------------------------
    def shard(self, mesh) -> "KernelModel":
        """Place the model's feature-dim arrays sharded over the mesh's
        "model" axis: omega (d, D) and bias/theta (D,) split their feature
        dim, thetas (N, D) additionally spreads agents over the batch axes.
        The big-D serving layout — a D=65536 model never needs a replicated
        feature axis on any device; `predict`, `evaluate` and
        `KernelServer` (constructed with the SAME mesh) consume the sharded
        arrays transparently (phi(x) @ theta contracts the sharded dim with
        one psum under GSPMD). Dims that don't divide the axis replicate.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.sharding import _div
        from repro.launch.mesh import batch_axes

        has_model = "model" in mesh.axis_names
        # cos_sin maps L spectral samples to 2L features: omega/bias split
        # their own L dim, theta its (possibly larger) D dim
        omega_l = self.rff_params.omega.shape[1]
        spec_feat = _div(omega_l, mesh, "model") if has_model else None
        feat = _div(self.num_features, mesh, "model") if has_model else None
        ba = batch_axes(mesh)

        def put(x, spec):
            return None if x is None else jax.device_put(
                x, NamedSharding(mesh, spec))

        params = dataclasses.replace(
            self.rff_params,
            omega=put(self.rff_params.omega, P(None, spec_feat)),
            bias=put(self.rff_params.bias, P(spec_feat)))
        lead = (_div(self.thetas.shape[0], mesh, ba)
                if self.thetas is not None and ba else None)
        return dataclasses.replace(
            self, rff_params=params,
            theta=put(self.theta, P(feat)),
            thetas=put(self.thetas, P(lead, feat)))

    # ---- scoring ---------------------------------------------------------
    def featurize(self, x: jax.Array, backend: str = "ref") -> jax.Array:
        """phi(x) on the chosen backend — the one routing point for every
        scoring path (predict, evaluate, KernelServer)."""
        if backend == "ref":
            return rff.featurize(self.rff_params, x)
        if backend == "fused":
            if self.rff_params.mapping != "cos_bias":
                raise ValueError(
                    "the fused Pallas featurizer implements the 'cos_bias' "
                    f"mapping (Eq. 13); this model uses "
                    f"{self.rff_params.mapping!r} — use backend='ref'")
            return featurize_fused(self.rff_params, x)
        raise ValueError(
            f"unknown predict backend {backend!r}; choose from "
            f"{PREDICT_BACKENDS}")

    def predict(self, x: jax.Array, *, batch_size: int | None = None,
                backend: str = "ref", agent: int | None = None) -> jax.Array:
        """Score inputs: f(x) = phi(x)' theta.

        x          — (..., d) inputs; leading dims are preserved (a bare (d,)
                     vector returns a scalar).
        batch_size — chunk the flattened batch through the featurizer in
                     host-visible pieces (bounds peak memory for the
                     "millions of users" scoring path); None = one pass.
        backend    — "ref" (eager reference) or "fused" (Pallas rff kernel).
        agent      — score with agent i's theta instead of the consensus
                     average (requires the per-agent stack).
        """
        if agent is None:
            theta = self.theta
        elif self.thetas is None:
            raise ValueError("this model was exported without per-agent "
                             "thetas; re-export with include_per_agent=True")
        else:
            theta = self.thetas[agent]

        x = jnp.asarray(x)
        scalar = x.ndim == 1
        if scalar:
            x = x[None]
        lead = x.shape[:-1]
        flat = x.reshape(-1, x.shape[-1])

        n = flat.shape[0]
        if batch_size is None or batch_size >= n:
            preds = self.featurize(flat, backend) @ theta
        else:
            if batch_size < 1:
                raise ValueError(f"batch_size must be >= 1, got {batch_size}")
            chunks = [self.featurize(flat[i:i + batch_size], backend) @ theta
                      for i in range(0, n, batch_size)]
            preds = jnp.concatenate(chunks)
        preds = preds.reshape(lead)
        return preds[0] if scalar else preds

    def score_rows(self, x: jax.Array, thetas: jax.Array, *,
                   backend: str = "ref") -> jax.Array:
        """Row-tagged scoring: row i of x (b, d) against row i of thetas
        (b, D) — the formulation the multi-tenant `KernelServer` runs after
        gathering each request's theta slot (`einsum('bd,bd->b')`).

        This is the bit-level reference for the many-model serving path,
        and it is jit-compiled for exactly that reason: the jitted
        featurize+reduce are row-stable for b >= 2, so a request's served
        rows are a pure function of (its own rows, its own theta),
        independent of which other tenants landed in the same padded
        bucket — while an eager evaluation would fuse the featurizer's
        constant scales differently and drift a few ulps. It differs from
        `predict`'s (b, D) @ (D,) matvec only by float reduction order
        (<~1e-6)."""
        if backend not in PREDICT_BACKENDS or (
                backend == "fused" and self.rff_params.mapping != "cos_bias"):
            self.featurize(jnp.zeros_like(jnp.asarray(x)), backend)  # raises
        return _score_rows_jit(self.rff_params.omega, self.rff_params.bias,
                               jnp.asarray(x), jnp.asarray(thetas),
                               self.rff_params.mapping, backend)

    def partial_fit(self, stream, config=None, *, labels=None,
                    progress_cb=None) -> tuple["KernelModel", Any]:
        """Warm-started online refinement: continue training this (batch-
        trained) model on a fresh per-agent minibatch stream, through
        `repro.api.fit_stream` — closing the deploy→refine loop.

        stream — a `StreamProblem` featurized with THIS model's RFF map,
                 or a raw (R, N, b, d) input stream (pass `labels`
                 (R, N, b)); raw streams are featurized here and the
                 consensus graph built from the config's graph family.
        config — the streaming FitConfig (algorithm / backend / comm
                 policy / rates); None = `online_coke` on the simulator,
                 one iteration per stream round, with this model's
                 provenance lam/rho/seed.

        Every agent warm-starts from the deployed parameters (the
        per-agent stack when the model kept one, else the consensus
        average). Returns (refined KernelModel, FitResult) — the model
        for serving, the result for the regret/bits trajectories.
        """
        from repro.api.fit import fit_stream  # local: avoid import cycle
        from repro.api.problems import (StreamProblem, build_graph,
                                        stream_from_arrays)

        if isinstance(stream, StreamProblem):
            if labels is not None:
                raise ValueError(
                    "a StreamProblem already carries its labels; pass "
                    "labels= only with a raw (R, N, b, d) input stream")
        else:
            if labels is None:
                raise ValueError(
                    "a raw stream needs its labels: partial_fit(x, "
                    "labels=y) with x (R, N, b, d) and y (R, N, b)")
            x = jnp.asarray(stream)
            if x.ndim != 4:
                raise ValueError(
                    f"a raw stream is x (R, N, b, d); got shape {x.shape}")
            num_agents = x.shape[1]
            if config is None:
                # provenance defaults: the lam/rho/seed/graph the model
                # was trained with
                lam = float(self.meta.get("lam", 1e-4))
                rho = float(self.meta.get("rho", 1e-2))
                seed = int(self.meta.get("seed", 0))
                config = self._stream_config(num_agents, x.shape[0],
                                             lam, rho)
            else:
                # an explicit config owns the problem spec end to end
                lam, rho = config.krr.lam, config.krr.rho
                seed = config.krr.seed
            graph = build_graph(config, num_agents, seed=seed)
            stream = stream_from_arrays(self.rff_params, x, labels, graph,
                                        lam=lam, rho=rho)
        if stream.feature_dim != self.num_features:
            raise ValueError(
                f"stream is featurized to D={stream.feature_dim} but this "
                f"model has D={self.num_features} features; featurize with "
                "the model's own RFF map (see "
                "repro.api.problems.stream_from_arrays)")
        if config is None:
            config = self._stream_config(
                stream.num_agents, stream.num_rounds,
                float(stream.lam), float(stream.rho))
        if (self.thetas is not None
                and self.thetas.shape[0] != stream.num_agents):
            raise ValueError(
                f"model carries {self.thetas.shape[0]} per-agent thetas "
                f"but the stream has {stream.num_agents} agents")

        theta0 = self.thetas if self.thetas is not None else self.theta
        result = fit_stream(config, stream=stream, theta0=theta0,
                            progress_cb=progress_cb)
        refined = result.to_model(self.rff_params)
        refined = dataclasses.replace(
            refined, bandwidth=self.bandwidth, kernel=self.kernel,
            meta={**refined.meta, "refined_from": dict(self.meta),
                  "warm_started": True})
        return refined, result

    def _stream_config(self, num_agents: int, num_rounds: int,
                       lam: float, rho: float):
        """The default partial_fit configuration: streaming COKE on the
        simulator, one iteration per stream round, on the graph family
        the model was trained with (to_model provenance) — refining on a
        different topology than the deployed consensus would silently
        change the dynamics."""
        from repro.api.config import FitConfig  # local: avoid import cycle
        from repro.configs.coke_krr import KRRConfig

        return FitConfig(
            algorithm="online_coke", num_iters=num_rounds,
            graph=str(self.meta.get("graph", "erdos_renyi")),
            graph_offsets=tuple(self.meta.get("graph_offsets", (1,))),
            krr=KRRConfig(num_agents=num_agents,
                          num_features=self.num_features,
                          bandwidth=self.bandwidth, lam=lam, rho=rho,
                          graph_p=float(self.meta.get("graph_p", 0.3)),
                          seed=int(self.meta.get("seed", 0))))

    def evaluate(self, x: jax.Array, y: jax.Array, *,
                 backend: str = "ref") -> dict[str, Any]:
        """The paper's generalization metrics on held-out data.

        With per-agent inputs x (N, S, d) / y (N, S) and a per-agent theta
        stack, `test_mse` is the Section-5 protocol — agent i scores its own
        shard with theta_i — computed exactly as the pre-KernelModel
        benchmarks did; `consensus_mse` scores every shard with the averaged
        theta (what a deployed node actually serves). With flat x (S, d) the
        two coincide.
        """
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        out: dict[str, Any] = {}
        if x.ndim == 3 and self.thetas is not None:
            phi = self.featurize(x, backend)                # (N, S, D)
            preds = jnp.einsum("nsd,nd->ns", phi, self.thetas)
            err = (y - preds) ** 2
            out["test_mse"] = float(jnp.mean(err))
            out["per_agent_mse"] = jnp.mean(err, axis=-1)
            consensus_preds = phi @ self.theta               # (N, S)
            out["consensus_mse"] = float(jnp.mean((y - consensus_preds) ** 2))
        else:
            preds = self.predict(x, backend=backend)
            out["test_mse"] = float(jnp.mean((y - preds) ** 2))
            out["consensus_mse"] = out["test_mse"]
        out["rmse"] = out["test_mse"] ** 0.5
        return out

    # ---- persistence -----------------------------------------------------
    def _array_tree(self) -> dict[str, jax.Array]:
        tree = {"omega": self.rff_params.omega,
                "bias": self.rff_params.bias,
                "theta": self.theta}
        if self.thetas is not None:
            tree["thetas"] = self.thetas
        return tree

    def save(self, path: str) -> None:
        """Write `<path>.npz` (arrays, via repro.ckpt) + `<path>.model.json`
        (mapping/kernel/bandwidth/meta + shapes for reload)."""
        ckpt.save(path, self._array_tree())
        sidecar = {
            "format": "repro.api.KernelModel/v1",
            "mapping": self.rff_params.mapping,
            "kernel": self.kernel,
            "bandwidth": self.bandwidth,
            "meta": self.meta,
            "model_id": self.model_id,
            "version": self.version,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in self._array_tree().items()},
        }
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.model.json.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(sidecar, f)
        os.replace(tmp, path + ".model.json")

    @classmethod
    def load(cls, path: str) -> "KernelModel":
        with open(path + ".model.json") as f:
            sidecar = json.load(f)
        if sidecar.get("format") != "repro.api.KernelModel/v1":
            raise ValueError(
                f"{path}.model.json is not a KernelModel artifact "
                f"(format={sidecar.get('format')!r})")
        like = {k: jax.ShapeDtypeStruct(tuple(s["shape"]), s["dtype"])
                for k, s in sidecar["arrays"].items()}
        tree, _ = ckpt.restore(path, like)
        params = rff.RFFParams(omega=jnp.asarray(tree["omega"]),
                               bias=jnp.asarray(tree["bias"]),
                               mapping=sidecar["mapping"])
        thetas = tree.get("thetas")
        version = sidecar.get("version")
        return cls(rff_params=params,
                   theta=jnp.asarray(tree["theta"]),
                   thetas=None if thetas is None else jnp.asarray(thetas),
                   bandwidth=float(sidecar["bandwidth"]),
                   kernel=sidecar["kernel"],
                   meta=sidecar["meta"],
                   model_id=sidecar.get("model_id"),
                   version=None if version is None else int(version))


def predict(model_or_result, x: jax.Array, **kw) -> jax.Array:
    """`repro.api.predict` — score inputs with a KernelModel or, as a
    convenience, directly with a FitResult (exported via `to_model()`)."""
    model = (model_or_result if isinstance(model_or_result, KernelModel)
             else model_or_result.to_model())
    return model.predict(x, **kw)
