"""Solver registry — the single place an algorithm plugs into `repro.api`.

A *solver* adapts one of the repo's algorithm implementations (DKLA Alg. 1,
COKE Alg. 2, the CTA diffusion baseline, streaming COKE, the centralized
ridge oracle) to a shared `init_state / step / metrics` contract so the one
`fit()` driver can run any of them. New algorithms register themselves with
`@register_solver("name")` and immediately gain every backend, the metric
recorder, and the sweep-friendly compiled fit loop.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax


@runtime_checkable
class Solver(Protocol):
    """The contract every registered algorithm implements.

    `prepare_host` runs once, eagerly, on the concrete problem (numpy-level
    precomputation such as Metropolis mixing weights); `prepare_traced` runs
    inside the jitted fit loop (e.g. the per-agent Cholesky factors) so its
    output lives in the compiled graph exactly as the legacy entry points
    built it. `step` and `metrics` are traced under `lax.scan`.
    """

    #: registry key, filled in by @register_solver
    name: str
    #: subset of {"simulator", "spmd", "fused"} this solver can run on
    backends: tuple[str, ...]
    #: repro.distributed.consensus strategy string for the SPMD/fused
    #: backends, or None when only the simulator applies
    consensus_strategy: str | None
    #: whether the solver threads a core.comm policy through its broadcast
    #: step; fit() rejects an explicit FitConfig.comm on unaware solvers
    comm_aware: bool

    # Streaming solvers (the online family) additionally carry, by
    # convention (checked via getattr, not the runtime protocol):
    #   streaming: bool            — fit_stream() accepts only these
    #   stream_backends: tuple     — subset of ("simulator", "spmd") the
    #                                streaming driver can route to (the
    #                                batch `backends` tuple stays the
    #                                fit() contract)
    #   warm_start(state, theta0)  — re-seed a fresh state from deployed
    #                                parameters (KernelModel.partial_fit)

    def prepare_host(self, problem: Any, ctx: Any) -> Any: ...

    def prepare_traced(self, problem: Any, ctx: Any, host_aux: Any) -> Any: ...

    def init_state(self, problem: Any, ctx: Any) -> Any: ...

    def step(self, problem: Any, ctx: Any, aux: Any, state: Any) -> Any: ...

    def metrics(self, problem: Any, ctx: Any, aux: Any,
                state: Any) -> dict[str, jax.Array]: ...

    def theta_of(self, state: Any) -> jax.Array: ...


_REGISTRY: dict[str, Solver] = {}


def register_solver(name: str):
    """Class decorator: instantiate the class and file it under `name`."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def _ensure_builtin_solvers() -> None:
    # Importing the module runs its @register_solver decorators. Lazy so
    # `repro.api.registry` has no import cycle with `repro.api.solvers`.
    from repro.api import solvers  # noqa: F401


def get_solver(name: str) -> Solver:
    _ensure_builtin_solvers()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; registered solvers: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def list_solvers() -> list[str]:
    _ensure_builtin_solvers()
    return sorted(_REGISTRY)


# The cross-axis admission rules (which solver × backend × exec × workload
# combinations run, and the nearest alternative when one does not) live in
# repro.api.capabilities as one declarative table; the drivers call its
# check_fit / check_stream / check_sweep entry points directly.
