"""Solver registry — the single place an algorithm plugs into `repro.api`.

A *solver* adapts one of the repo's algorithm implementations (DKLA Alg. 1,
COKE Alg. 2, the CTA diffusion baseline, streaming COKE, the centralized
ridge oracle) to a shared `init_state / step / metrics` contract so the one
`fit()` driver can run any of them. New algorithms register themselves with
`@register_solver("name")` and immediately gain every backend, the metric
recorder, and the sweep-friendly compiled fit loop.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax


@runtime_checkable
class Solver(Protocol):
    """The contract every registered algorithm implements.

    `prepare_host` runs once, eagerly, on the concrete problem (numpy-level
    precomputation such as Metropolis mixing weights); `prepare_traced` runs
    inside the jitted fit loop (e.g. the per-agent Cholesky factors) so its
    output lives in the compiled graph exactly as the legacy entry points
    built it. `step` and `metrics` are traced under `lax.scan`.
    """

    #: registry key, filled in by @register_solver
    name: str
    #: subset of {"simulator", "spmd", "fused"} this solver can run on
    backends: tuple[str, ...]
    #: repro.distributed.consensus strategy string for the SPMD/fused
    #: backends, or None when only the simulator applies
    consensus_strategy: str | None
    #: whether the solver threads a core.comm policy through its broadcast
    #: step; fit() rejects an explicit FitConfig.comm on unaware solvers
    comm_aware: bool

    # Streaming solvers (the online family) additionally carry, by
    # convention (checked via getattr, not the runtime protocol):
    #   streaming: bool            — fit_stream() accepts only these
    #   stream_backends: tuple     — subset of ("simulator", "spmd") the
    #                                streaming driver can route to (the
    #                                batch `backends` tuple stays the
    #                                fit() contract)
    #   warm_start(state, theta0)  — re-seed a fresh state from deployed
    #                                parameters (KernelModel.partial_fit)

    def prepare_host(self, problem: Any, ctx: Any) -> Any: ...

    def prepare_traced(self, problem: Any, ctx: Any, host_aux: Any) -> Any: ...

    def init_state(self, problem: Any, ctx: Any) -> Any: ...

    def step(self, problem: Any, ctx: Any, aux: Any, state: Any) -> Any: ...

    def metrics(self, problem: Any, ctx: Any, aux: Any,
                state: Any) -> dict[str, jax.Array]: ...

    def theta_of(self, state: Any) -> jax.Array: ...


_REGISTRY: dict[str, Solver] = {}


def register_solver(name: str):
    """Class decorator: instantiate the class and file it under `name`."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def _ensure_builtin_solvers() -> None:
    # Importing the module runs its @register_solver decorators. Lazy so
    # `repro.api.registry` has no import cycle with `repro.api.solvers`.
    from repro.api import solvers  # noqa: F401


def get_solver(name: str) -> Solver:
    _ensure_builtin_solvers()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown solver {name!r}; registered solvers: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def list_solvers() -> list[str]:
    _ensure_builtin_solvers()
    return sorted(_REGISTRY)


def ensure_primal_supported(config, solver: Solver) -> None:
    """Reject forcing an exact (21a) solve on a solver that has no (21a)
    primal subproblem — silently running a different update would be worse
    than failing. Shared by fit() and sweep()."""
    if config.primal in ("cholesky", "cg") and not getattr(
            solver, "primal_aware", False):
        raise ValueError(
            f"solver {config.algorithm!r} has no (21a) primal subproblem "
            f"for primal={config.primal!r} to solve; leave primal='auto' "
            "or pick an ADMM solver (dkla/coke)")


def ensure_exec_supported(config, solver: Solver) -> None:
    """The exec="gossip" admission checks, shared by fit(), fit_stream()
    and sweep(): only solvers with asynchronous update semantics
    (gossip_aware — the ADMM and streaming families) can run under
    sampled participation, gossip needs a static graph, and churn
    (population dynamics) is implemented on the vectorized simulator with
    a degree-tracking primal."""
    if config.exec != "gossip":
        return
    if not getattr(solver, "gossip_aware", False):
        raise ValueError(
            f"solver {config.algorithm!r} has no gossip execution "
            "semantics; use exec='sync' or pick the ADMM (dkla/coke) or "
            "streaming (online_dkla/online_coke/qc_odkla) families")
    if config.topology is not None:
        raise ValueError(
            "gossip execution samples participants on a static consensus "
            "graph; drop FitConfig.topology or use exec='sync'")
    if config.churn is not None:
        if config.backend != "simulator":
            raise ValueError(
                "churn (agent join/leave, stragglers) is implemented on "
                f"the vectorized simulator backend, not {config.backend!r}")
        if config.primal == "cholesky":
            raise ValueError(
                "churn makes the graph degrees time-varying; the "
                "prefactored Cholesky primal cannot follow them — use "
                "primal='auto', 'cg' or 'gradient'")


def ensure_personalization_supported(config, solver: Solver) -> None:
    """The FitConfig.personalization admission checks, shared by fit(),
    fit_stream() and sweep(): only the ADMM and streaming families have
    the proximity-penalty update a learned weighted graph plugs into, the
    fused kernel bakes the graph degree in statically, and the
    prefactored Cholesky primal cannot follow time-varying learned
    degrees. (Structural conflicts — topology schedules, churn — are
    rejected by FitConfig.__post_init__ itself.)"""
    if config.personalization is None:
        return
    if not getattr(solver, "personalization_aware", False):
        raise ValueError(
            f"solver {config.algorithm!r} has no consensus-penalty term "
            "for a learned collaboration graph to reweight; pick the ADMM "
            "(dkla/coke) or streaming (online_dkla/online_coke/qc_odkla) "
            "families, or drop FitConfig.personalization")
    if config.backend == "fused":
        raise ValueError(
            "the fused Pallas coke_update kernel bakes the graph degree "
            "in as a static parameter; a learned graph is time-varying — "
            "use backend='simulator' or 'spmd'")
    if config.primal == "cholesky":
        raise ValueError(
            "a learned collaboration graph makes the degrees time-"
            "varying; the prefactored Cholesky primal cannot follow them "
            "— use primal='auto', 'cg' or 'gradient'")


def ensure_stream_supported(config, solver: Solver) -> None:
    """The fit_stream() admission checks: only the streaming solvers take a
    StreamProblem, and only on the backends their online update is wired
    for. Shared by fit_stream() and KernelModel.partial_fit()."""
    if not getattr(solver, "streaming", False):
        raise ValueError(
            f"solver {config.algorithm!r} is a batch algorithm; fit_stream "
            "drives the streaming family (online_dkla/online_coke/"
            "qc_odkla) — use fit() instead")
    stream_backends = getattr(solver, "stream_backends", ())
    if config.backend not in stream_backends:
        raise ValueError(
            f"streaming solver {config.algorithm!r} supports backends "
            f"{stream_backends}, not {config.backend!r}")
    if config.topology is not None:
        raise ValueError(
            "the streaming solvers run on a static consensus graph; drop "
            "FitConfig.topology or use the batch ADMM solvers")
    ensure_primal_supported(config, solver)
