"""`FitConfig` / `FitResult` — the unified run description and run record.

`FitConfig` composes the paper-level problem spec (`KRRConfig`), the censor
schedule, the graph family, the algorithm name (a registry key) and the
backend choice into one frozen object; `fit(config)` is the only driver.

The censor thresholds (v, mu) are deliberately *traced* through the compiled
fit loop (see `SolveContext.censor`): a sweep over schedules reuses one
compiled scan instead of retracing per float pair, which the legacy
`core.admm.run(static schedule)` entry point could not do.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.coke_krr import KRRConfig
from repro.core import comm as comm_mod
from repro.core.graph import TopologySchedule

BACKENDS = ("simulator", "spmd", "fused")


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Everything `fit()` needs to run one algorithm on one problem."""

    algorithm: str = "coke"          # registry key: see repro.api.list_solvers()
    krr: KRRConfig = KRRConfig()     # dataset / RF / lam / rho / graph_p spec
    backend: str = "simulator"       # simulator | spmd | fused

    # communication policy: a core.comm Chain / stage / CensorSchedule.
    # None = the legacy censor knobs below, i.e. Chain([Censor(v, mu)]).
    comm: object | None = None

    # DEPRECATED spelling of comm=Chain([Censor(v, mu)]); None = inherit
    # from krr. Mutually exclusive with `comm`.
    censor_v: float | None = None
    censor_mu: float | None = None

    # execution semantics — the async axis (see repro.core.gossip):
    #   "sync"   = bulk-synchronous: every agent computes and exchanges
    #              every iteration (the paper's Algorithms 1/2 as written);
    #   "gossip" = per iteration a Bernoulli(participation) or fixed-size
    #              (gossip_size) sample of agents runs the primal step and
    #              broadcasts; everyone else holds state, neighbors read
    #              stale values, duals are delayed-but-correct, and
    #              non-participants pay zero bits. participation=1.0 with
    #              no churn reproduces "sync" (bit-for-bit on deg-2
    #              graphs — the conformance pin).
    exec: str = "sync"
    participation: float = 1.0       # gossip: Bernoulli wake-up rate
    gossip_size: int | None = None   # gossip: fixed-size sample (overrides
    #                                  the Bernoulli rate when set)
    # population dynamics (simulator gossip only): straggler slowdowns and
    # scheduled agent join/leave events — a core.gossip.ChurnSchedule
    churn: object | None = None

    # time-varying consensus graph; None = the static `graph` family below.
    # The spmd/fused backends require schedule.offsets (circulant lowering).
    topology: TopologySchedule | None = None

    # personalization — the learned-collaboration-graph axis (a
    # core.personalize.Personalization): the fit alternates solver steps
    # with a graph-update step that relearns a mutual top-k adjacency from
    # theta affinities and relaxes strict consensus to a similarity-
    # weighted proximity penalty (per-agent models over non-IID data).
    # None = today's consensus path, bit-for-bit.
    personalization: object | None = None

    num_iters: int | None = None     # None = krr.num_iters

    # primal update — the big-D axis:
    #   "auto"     = closed-form Cholesky for the quadratic loss up to
    #                admm.CG_CROSSOVER_DIM features, matrix-free CG above
    #                (the crossover where (D, D) factors stop fitting);
    #   "cholesky" = force the prefactored exact solve (O(N D^2) memory);
    #   "cg"       = force the Jacobi-preconditioned conjugate-gradient
    #                solve of (21a) — only ever applies phi.T @ (phi @ v),
    #                no (D, D) materialization at any D;
    #   "gradient" = the inexact GD inner solver (any loss; what the SPMD
    #                runtime's one-step update approximates — use it for
    #                legacy cross-backend parity).
    primal: str = "auto"
    inner_steps: int = 50            # gradient primal: GD steps per iteration
    inner_lr: float = 0.1            # gradient primal / SPMD optimizer lr
    cg_tol: float = 1e-8             # cg primal: residual stop
    cg_maxiter: int = 64             # cg primal: step cap per ADMM iteration

    cta_lr: float = 0.9              # CTA diffusion stepsize
    online_lr: float = 0.3           # streaming family gradient stepsize
    online_batch: int = 16           # streaming minibatch per round

    # streaming workload (fit_stream): the generator kind build_stream uses
    # when no StreamProblem is passed — "stationary" | "drift" (concept
    # drift) | "shift" (covariate shift); see data.synthetic.stream_synthetic
    stream: str = "stationary"
    # qc_odkla proximal coefficient eta: the linearized-ADMM primal solves
    # to theta - g/(eta + 2 rho deg_i). None = use the gradient stepsize
    # online_lr instead (the degenerate case in which qc_odkla is exactly
    # online_coke — the identity contract the streaming tests pin).
    qc_eta: float | None = None

    # graph family ("erdos_renyi" uses krr.graph_p; spmd/fused backends
    # require the circulant family — it is what lowers to collective-permute)
    graph: str = "erdos_renyi"       # erdos_renyi | ring | circulant | full
    graph_offsets: tuple[int, ...] = (1,)

    # fit-loop plumbing
    chunk_size: int | None = None    # scan chunk between host callbacks
    record_oracle_distance: bool = False

    def __post_init__(self):
        from repro.core.admm import PRIMAL_MODES  # local: avoid cycle

        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; choose from {BACKENDS}")
        if self.primal not in PRIMAL_MODES:
            raise ValueError(
                f"unknown primal mode {self.primal!r}; choose from "
                f"{PRIMAL_MODES}")
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(
                f"chunk_size must be >= 1 or None, got {self.chunk_size}")
        from repro.data.synthetic import STREAM_KINDS  # local: keep light
        if self.stream not in STREAM_KINDS:
            raise ValueError(
                f"unknown stream kind {self.stream!r}; choose from "
                f"{STREAM_KINDS}")
        if self.qc_eta is not None and self.qc_eta <= 0:
            raise ValueError(
                f"qc_eta must be positive (or None to reuse online_lr), "
                f"got {self.qc_eta}")
        from repro.core.gossip import EXEC_MODES, ChurnSchedule
        if self.exec not in EXEC_MODES:
            raise ValueError(
                f"unknown exec mode {self.exec!r}; choose from {EXEC_MODES}")
        if self.exec == "gossip":
            if not 0.0 < self.participation <= 1.0:
                raise ValueError(
                    f"participation must be in (0, 1], got "
                    f"{self.participation}")
            if self.gossip_size is not None and self.gossip_size < 1:
                raise ValueError(
                    f"gossip_size must be >= 1 or None, got "
                    f"{self.gossip_size}")
            if self.churn is not None and not isinstance(self.churn,
                                                         ChurnSchedule):
                raise ValueError(
                    "churn must be a repro.core.gossip.ChurnSchedule, got "
                    f"{type(self.churn).__name__}")
        if self.personalization is not None:
            from repro.core.personalize import Personalization
            if not isinstance(self.personalization, Personalization):
                raise ValueError(
                    "personalization must be a repro.core.personalize."
                    "Personalization, got "
                    f"{type(self.personalization).__name__}")
        # the cross-axis admission — one declarative table, shared with
        # the drivers' solver-scoped checks and the README matrix
        from repro.api.capabilities import check_config
        check_config(self)
        if self.comm is not None:
            comm_mod.as_chain(self.comm)  # fail fast on non-policies

    # ---- resolved knobs --------------------------------------------------
    @property
    def resolved_comm(self) -> "comm_mod.Chain":
        """The communication policy as a Chain (the one the solvers run).

        `comm` wins when set; otherwise the legacy (censor_v, censor_mu)
        knobs — themselves defaulting to the KRRConfig — map onto the
        equivalent Chain([Censor(v, mu)]) migration shim.
        """
        if self.comm is not None:
            return comm_mod.as_chain(self.comm)
        v, mu = self.resolved_censor
        return comm_mod.Chain((comm_mod.Censor(v, mu),))

    @property
    def resolved_censor(self) -> tuple[float, float]:
        """(v, mu) of the policy's first Censor stage ((0, 0) when the
        policy does not censor) — kept for provenance metadata and the
        legacy accessors."""
        if self.comm is not None:
            for s in comm_mod.as_chain(self.comm).stages:
                if isinstance(s, comm_mod.Censor):
                    return float(s.v), float(s.mu)
            return 0.0, 0.0
        v = self.krr.censor_v if self.censor_v is None else self.censor_v
        mu = self.krr.censor_mu if self.censor_mu is None else self.censor_mu
        return float(v), float(mu)

    @property
    def resolved_iters(self) -> int:
        return self.krr.num_iters if self.num_iters is None else self.num_iters

    def replace(self, **kw) -> "FitConfig":
        return dataclasses.replace(self, **kw)


@partial(jax.tree_util.register_dataclass,
         data_fields=("comm", "topology", "gossip", "personalization"),
         meta_fields=("primal", "inner_steps", "inner_lr", "cg_tol",
                      "cg_maxiter", "cta_lr", "online_lr", "online_batch",
                      "qc_eta", "exec", "pz_warmup"))
@dataclasses.dataclass(frozen=True)
class SolveContext:
    """The solver-facing slice of a FitConfig, shaped for jit: the comm
    policy's numeric knobs (v, mu, bits, p), the topology schedule's
    adjacency stack, and the gossip plan's participation/liveness arrays
    are array *data* (traced — policy sweeps share one compilation);
    everything else is static metadata."""

    comm: comm_mod.Chain             # policy with float32 array leaves
    topology: TopologySchedule | None = None
    # compiled gossip execution plan (core.gossip.GossipPlan) when
    # exec == "gossip"; None under synchronous execution
    gossip: object | None = None
    # learned-collaboration-graph axis (core.personalize.Personalization);
    # its numeric scale is array data, so scale sweeps share a compilation
    personalization: object | None = None
    primal: str = "auto"
    inner_steps: int = 50
    inner_lr: float = 0.1
    cg_tol: float = 1e-8
    cg_maxiter: int = 64
    cta_lr: float = 0.9
    online_lr: float = 0.3
    online_batch: int = 16
    qc_eta: float | None = None
    exec: str = "sync"
    # personalized warmup phase: the fit driver runs iterations
    # 1..warmup as a SEPARATE compiled program that takes the exact
    # static-consensus code path (no graph machinery in the scan body at
    # all — only the extra per-agent metric readout), so the pre-refresh
    # prefix is bit-identical to the consensus run BY CONSTRUCTION, not
    # by XLA fusion luck. Static metadata: each phase is its own trace.
    pz_warmup: bool = False

    @classmethod
    def from_config(cls, config: FitConfig,
                    num_agents: int | None = None) -> "SolveContext":
        from repro.core.gossip import ChurnSchedule  # local: avoid cycle

        chain = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                             config.resolved_comm)
        gossip = None
        if config.exec == "gossip":
            if num_agents is None:
                raise ValueError(
                    "exec='gossip' needs the agent count to compile its "
                    "participation/churn plan; pass num_agents")
            sched = config.churn if config.churn is not None \
                else ChurnSchedule()
            gossip = sched.plan(num_agents,
                                participation=config.participation,
                                size=config.gossip_size)
        pz = config.personalization
        if pz is not None:
            pz = dataclasses.replace(
                pz, scale=jnp.asarray(pz.scale, jnp.float32))
        return cls(comm=chain,
                   topology=config.topology,
                   gossip=gossip,
                   personalization=pz,
                   primal=config.primal,
                   inner_steps=config.inner_steps,
                   inner_lr=config.inner_lr,
                   cg_tol=config.cg_tol,
                   cg_maxiter=config.cg_maxiter,
                   cta_lr=config.cta_lr,
                   online_lr=config.online_lr,
                   online_batch=config.online_batch,
                   qc_eta=config.qc_eta,
                   exec=config.exec)


@dataclasses.dataclass(frozen=True)
class FitResult:
    """What `fit()` returns for every algorithm and backend: the final
    solver state plus per-iteration metric trajectories."""

    config: FitConfig
    state: Any
    history: dict[str, jax.Array]    # each (num_iters,)
    theta: jax.Array                 # (N, D) final per-agent parameters
    # the RFF map the thetas were trained against; populated when fit()
    # built the problem itself (pass it to to_model() otherwise)
    rff_params: Any = None

    # ---- trajectory accessors (the paper's evaluation quantities) --------
    @property
    def train_mse(self) -> jax.Array:
        return self.history["train_mse"]

    @property
    def comms(self) -> jax.Array:
        return self.history["comms"]

    @property
    def bits(self) -> jax.Array:
        """Cumulative bits transmitted network-wide per iteration — the
        cost axis the accuracy-vs-bits tradeoff curves are drawn in."""
        return self.history["bits"]

    @property
    def consensus_gap(self) -> jax.Array:
        return self.history["consensus_gap"]

    def distance_to(self, theta_star: jax.Array) -> float:
        """max_i ||theta_i - theta*|| of the final iterate (Thm 1/2 metric)."""
        return float(jnp.max(jnp.linalg.norm(self.theta - theta_star,
                                             axis=-1)))

    def summary(self) -> dict[str, float]:
        # vector-valued entries (e.g. the personalized per_agent_mse
        # trajectory, (K, N)) summarize as the mean of their final row
        out = {k: (float(jnp.mean(v[-1])) if jnp.ndim(v[-1]) else
                   float(v[-1]))
               for k, v in self.history.items()}
        out["num_iters"] = int(self.history["train_mse"].shape[0])
        return out

    @property
    def learned_adjacency(self) -> jax.Array | None:
        """The final learned collaboration graph of a personalized fit
        ((N, N) weighted, symmetric, zero-diagonal); None when the run
        was not personalized."""
        if self.config.personalization is None:
            return None
        A = getattr(self.state, "adjacency", None)  # PersonalizedState &c
        if A is not None:
            return A
        if isinstance(self.state, tuple):   # spmd: (params, cstate) carry
            return self.state[1]["adjacency"]
        return None

    def _model_meta(self) -> dict:
        krr = self.config.krr
        v, mu = self.config.resolved_censor
        return {
            "algorithm": self.config.algorithm,
            "backend": self.config.backend,
            "exec": self.config.exec,
            "num_iters": self.config.resolved_iters,
            "censor_v": v, "censor_mu": mu,
            "comm": self.config.resolved_comm.describe(),
            "dataset": krr.dataset, "num_agents": krr.num_agents,
            "num_features": krr.num_features, "lam": krr.lam,
            "rho": krr.rho, "seed": krr.seed, "graph": self.config.graph,
            # the full topology provenance (JSON-friendly), so
            # KernelModel.partial_fit can rebuild the trained-on graph —
            # not just its family name
            "graph_offsets": list(self.config.graph_offsets),
            "graph_p": krr.graph_p,
        }

    def _resolved_rff(self, rff_params):
        params = self.rff_params if rff_params is None else rff_params
        if params is None:
            raise ValueError(
                "this FitResult has no RFF parameters (fit() was given a "
                "pre-built problem); pass them explicitly: "
                "result.to_model(built.rff_params)")
        return params

    def to_model(self, rff_params=None, *, include_per_agent: bool = True):
        """Package the fitted thetas with their RFF map into a deployable
        `repro.api.KernelModel` (predict / evaluate / save / serve).

        rff_params — required when fit() was handed a pre-built problem
                     (take it from `build_problem(...).rff_params`);
                     inferred automatically when fit() built the problem.
        include_per_agent — keep the (N, D) per-agent stack alongside the
                     consensus average (needed for the paper's per-agent
                     test protocol; drop it for a minimal serving artifact).
        """
        from repro.api.model import KernelModel  # local: avoid import cycle

        if self.config.personalization is not None:
            raise ValueError(
                "this fit was personalized: its per-agent thetas were "
                "never meant to agree, and consensus-averaging them "
                "destroys the per-cluster models — use to_models() (one "
                "KernelModel per agent) or index result.theta yourself")
        params = self._resolved_rff(rff_params)
        krr = self.config.krr
        return KernelModel(
            rff_params=params,
            theta=jnp.mean(self.theta, axis=0),
            thetas=self.theta if include_per_agent else None,
            bandwidth=krr.bandwidth, kernel="gaussian",
            meta=self._model_meta())

    def to_models(self, rff_params=None) -> list:
        """One deployable `KernelModel` per agent — the personalized
        serving path (also works on a consensus fit, where the N models
        are near-identical). Model i predicts with theta_i alone; its
        meta records the agent index and the personalization knobs."""
        from repro.api.model import KernelModel  # local: avoid import cycle

        params = self._resolved_rff(rff_params)
        krr = self.config.krr
        meta = self._model_meta()
        pz = self.config.personalization
        if pz is not None:
            meta["personalization"] = {
                "k": pz.k, "every": pz.every, "warmup": pz.warmup,
                "affinity": pz.affinity, "scale": float(pz.scale)}
        return [KernelModel(rff_params=params, theta=self.theta[i],
                            thetas=None, bandwidth=krr.bandwidth,
                            kernel="gaussian", meta={**meta, "agent": i})
                for i in range(self.theta.shape[0])]

    def publish_models(self, registry, *, prefix: str = "agent",
                       rff_params=None) -> list[tuple[str, int]]:
        """Publish every per-agent model into a `repro.serve.ModelRegistry`
        as `{prefix}-{i:03d}` — the personalized fit -> many-model serving
        hand-off (KernelServer pages them through its ThetaStore by id).
        Returns the [(model_id, version), ...] it published."""
        out = []
        for i, model in enumerate(self.to_models(rff_params)):
            model_id = f"{prefix}-{i:03d}"
            out.append((model_id, registry.publish(model_id, model)))
        return out
