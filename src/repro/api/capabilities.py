"""The solver × backend × exec × workload capability table.

Every cross-axis admission rule — which knob combinations a FitConfig may
compose, and which (solver, backend, exec, workload) cells fit() /
fit_stream() / sweep() can actually run — lives HERE as declarative data,
not as scattered ValueErrors. `FitConfig.__post_init__` consults
CONFIG_RULES (no solver needed); the drivers consult RUN_RULES through the
`check_fit` / `check_stream` / `check_sweep` entry points once the solver
is resolved.

Each rule names the nearest supported alternative, so every rejection
tells the user the closest thing that DOES run. The README's support
matrix is *generated* from this table (`support_matrix()` /
`python -m repro.api.capabilities`), and `tests/test_capabilities.py`
pins both directions: every unsupported combination raises with its
alternative, and the committed README block matches the table.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class Rule:
    """One unsupported region of the axis space, declaratively.

    when        — ((axis, match), ...): the rule fires when EVERY axis in
                  the view matches (a tuple match means "value is one of").
    reason      — why the combination cannot run; `{axis}` placeholders
                  format from the view (legacy error substrings preserved —
                  they are test contracts).
    alternative — the nearest supported combination, appended to the
                  error so every rejection names a way forward.
    """

    id: str
    when: tuple[tuple[str, Any], ...]
    reason: str
    alternative: str

    def matches(self, view: dict[str, Any]) -> bool:
        for axis, want in self.when:
            have = view[axis]
            if isinstance(want, tuple):
                if have not in want:
                    return False
            elif have != want:
                return False
        return True


#: rules FitConfig.__post_init__ can decide alone (no solver resolution)
CONFIG_RULES: tuple[Rule, ...] = (
    Rule(
        id="sync-gossip-knobs",
        when=(("exec", "sync"), ("gossip_knobs", True)),
        reason="participation/gossip_size/churn are gossip-execution "
               "knobs; set exec='gossip' to use them",
        alternative="exec='gossip' with the same knobs",
    ),
    Rule(
        id="comm-censor-knobs",
        when=(("comm", True), ("censor_knobs", True)),
        reason="censor_v/censor_mu are the legacy spelling of "
               "comm=Chain([Censor(v, mu)]); pass one or the other, "
               "not both",
        alternative="fold the thresholds into the comm chain and drop "
                    "censor_v/censor_mu",
    ),
    Rule(
        id="personalization-topology",
        when=(("personalization", True), ("topology", True)),
        reason="personalization learns its own collaboration graph; it "
               "does not compose with a scripted FitConfig.topology "
               "schedule",
        alternative="drop FitConfig.topology (keep the learned graph) or "
                    "drop personalization (keep the schedule)",
    ),
    Rule(
        id="personalization-churn",
        when=(("personalization", True), ("churn", True)),
        reason="personalization does not compose with churn: a learned "
               "graph over a changing population is ill-defined (joiners "
               "restart at theta = 0, hijacking the affinity ranking)",
        alternative="personalization with exec='gossip' participation "
                    "sampling (no churn), or churn without "
                    "personalization",
    ),
)

#: rules needing the resolved solver; `mode` scopes each rule to the
#: driver(s) it applies to ("batch" = fit, "stream" = fit_stream,
#: "sweep" = sweep — which runs the batch admission first)
RUN_RULES: tuple[Rule, ...] = (
    Rule(
        id="solver-backend",
        when=(("mode", "batch"), ("backend_supported", False)),
        reason="solver {algorithm} supports backends {solver_backends}, "
               "not {backend}",
        alternative="backend='simulator' (every solver runs there)",
    ),
    Rule(
        id="comm-unaware-solver",
        when=(("mode", "batch"), ("comm", True), ("solver_comm", False)),
        reason="solver {algorithm} does not thread a communication "
               "policy (it transmits unconditionally); drop "
               "FitConfig.comm or pick a comm-aware algorithm "
               "(dkla/coke/online_coke)",
        alternative="algorithm='coke' with the same comm chain",
    ),
    Rule(
        id="topology-unaware-solver",
        when=(("mode", "batch"), ("topology", True),
              ("solver_topology", False)),
        reason="solver {algorithm} does not support a time-varying "
               "topology schedule; drop FitConfig.topology or pick "
               "dkla/coke",
        alternative="algorithm='coke' with the same schedule",
    ),
    Rule(
        id="primal-unaware-solver",
        when=(("primal", ("cholesky", "cg")), ("solver_primal", False)),
        reason="solver {algorithm} has no (21a) primal subproblem for "
               "primal={primal} to solve; leave primal='auto' or pick an "
               "ADMM solver (dkla/coke)",
        alternative="algorithm='coke' with the same primal mode",
    ),
    Rule(
        id="gossip-unaware-solver",
        when=(("exec", "gossip"), ("solver_gossip", False)),
        reason="solver {algorithm} has no gossip execution semantics; "
               "use exec='sync' or pick the ADMM (dkla/coke) or "
               "streaming (online_dkla/online_coke/qc_odkla) families",
        alternative="algorithm='coke' under exec='gossip'",
    ),
    Rule(
        id="gossip-topology",
        when=(("exec", "gossip"), ("topology", True)),
        reason="gossip execution samples participants on a static "
               "consensus graph; drop FitConfig.topology or use "
               "exec='sync'",
        alternative="exec='sync' with the same topology schedule",
    ),
    Rule(
        id="churn-fused",
        when=(("churn", True), ("backend", "fused")),
        reason="churn makes the graph degrees traced data; the fused "
               "Pallas kernels (the coke_megastep megakernel and the "
               "coke_update combine) bake the degree in as a static "
               "parameter",
        alternative="backend='spmd' (alive-masked ring permutes) or "
                    "'simulator' with the same ChurnSchedule",
    ),
    Rule(
        id="churn-cholesky",
        when=(("churn", True), ("primal", "cholesky")),
        reason="churn makes the graph degrees time-varying; the "
               "prefactored Cholesky primal cannot follow them — use "
               "primal='auto', 'cg' or 'gradient'",
        alternative="primal='cg' (exact and degree-tracking)",
    ),
    Rule(
        id="personalization-unaware-solver",
        when=(("personalization", True), ("solver_pz", False)),
        reason="solver {algorithm} has no consensus-penalty term for a "
               "learned collaboration graph to reweight; pick the ADMM "
               "(dkla/coke) or streaming (online_dkla/online_coke/"
               "qc_odkla) families, or drop FitConfig.personalization",
        alternative="algorithm='coke' with the same Personalization",
    ),
    Rule(
        id="personalization-fused",
        when=(("personalization", True), ("backend", "fused")),
        reason="the fused Pallas kernels bake the graph degree and ring "
               "offsets in as static parameters; a learned graph is "
               "time-varying — use backend='simulator' or 'spmd'",
        alternative="backend='spmd' with the same Personalization",
    ),
    Rule(
        id="personalization-cholesky",
        when=(("personalization", True), ("primal", "cholesky")),
        reason="a learned collaboration graph makes the degrees time-"
               "varying; the prefactored Cholesky primal cannot follow "
               "them — use primal='auto', 'cg' or 'gradient'",
        alternative="primal='cg' (exact and degree-tracking)",
    ),
    Rule(
        id="stream-batch-solver",
        when=(("mode", "stream"), ("solver_streaming", False)),
        reason="solver {algorithm} is a batch algorithm; fit_stream "
               "drives the streaming family (online_dkla/online_coke/"
               "qc_odkla) — use fit() instead",
        alternative="fit() with the same config",
    ),
    Rule(
        id="stream-backend",
        when=(("mode", "stream"), ("solver_streaming", True),
              ("stream_backend_supported", False)),
        reason="streaming solver {algorithm} supports backends "
               "{stream_backends}, not {backend}",
        alternative="backend='simulator' or 'spmd' via fit_stream",
    ),
    Rule(
        id="stream-topology",
        when=(("mode", "stream"), ("topology", True)),
        reason="the streaming solvers run on a static consensus graph; "
               "drop FitConfig.topology or use the batch ADMM solvers",
        alternative="algorithm='coke' through fit() with the schedule",
    ),
    Rule(
        id="sweep-streaming",
        when=(("mode", "sweep"), ("solver_streaming", True)),
        reason="sweep vmaps the batch fit program; streaming solver "
               "{algorithm} takes a StreamProblem",
        alternative="fit_stream() per policy cell, or sweep a batch "
                    "solver (dkla/coke)",
    ),
    Rule(
        id="sweep-backend",
        when=(("mode", "sweep"), ("backend", ("spmd", "fused"))),
        reason="sweep vmaps the in-process simulator loop; run backend="
               "{backend} cells individually through fit()",
        alternative="backend='simulator' (the whole grid is one compiled "
                    "program)",
    ),
)


def _config_view(config) -> dict[str, Any]:
    return {
        "exec": config.exec,
        "backend": config.backend,
        "primal": config.primal,
        "comm": config.comm is not None,
        "censor_knobs": (config.censor_v is not None
                         or config.censor_mu is not None),
        "gossip_knobs": (config.participation != 1.0
                         or config.gossip_size is not None
                         or config.churn is not None),
        "churn": config.churn is not None,
        "topology": config.topology is not None,
        "personalization": config.personalization is not None,
    }


def _run_view(config, solver, mode: str) -> dict[str, Any]:
    view = _config_view(config)
    stream_backends = getattr(solver, "stream_backends", ())
    view.update({
        "mode": mode,
        "algorithm": repr(config.algorithm),
        "solver_backends": repr(tuple(solver.backends)),
        "stream_backends": repr(tuple(stream_backends)),
        "backend_supported": config.backend in solver.backends,
        "stream_backend_supported": config.backend in stream_backends,
        "solver_comm": getattr(solver, "comm_aware", False),
        "solver_topology": getattr(solver, "topology_aware", False),
        "solver_primal": getattr(solver, "primal_aware", False),
        "solver_gossip": getattr(solver, "gossip_aware", False),
        "solver_pz": getattr(solver, "personalization_aware", False),
        "solver_streaming": getattr(solver, "streaming", False),
    })
    return view


def _enforce(view: dict[str, Any], rules: tuple[Rule, ...]) -> None:
    for rule in rules:
        if rule.matches(view):
            raise ValueError(
                rule.reason.format(**view)
                + f" — nearest supported: {rule.alternative}")


def check_config(config) -> None:
    """The solver-free cross-axis admission — FitConfig.__post_init__."""
    _enforce(_config_view(config), CONFIG_RULES)


def check_fit(config, solver) -> None:
    """The batch-driver admission (fit)."""
    _enforce(_run_view(config, solver, "batch"), RUN_RULES)


def check_stream(config, solver) -> None:
    """The streaming-driver admission (fit_stream / partial_fit)."""
    _enforce(_run_view(config, solver, "stream"), RUN_RULES)


def check_sweep(config, solver) -> None:
    """The sweep admission: the vmapped grid runs the simulator batch
    program, so a cell must pass both the sweep- and batch-scoped rules."""
    _enforce(_run_view(config, solver, "sweep"), RUN_RULES)
    _enforce(_run_view(config, solver, "batch"), RUN_RULES)


# ---------------------------------------------------------------------------
# The README support matrix, generated from the same table
# ---------------------------------------------------------------------------

BEGIN_MARK = "<!-- BEGIN support-matrix (generated: python -m repro.api.capabilities) -->"
END_MARK = "<!-- END support-matrix -->"

#: the probe FitConfig knobs per feature column; every cell of the matrix
#: is decided by running the SAME rules the drivers enforce
_FEATURE_PROBES: tuple[tuple[str, dict[str, Any]], ...] = (
    ("`exec=\"sync\"`", {}),
    ("`exec=\"gossip\"`", {"exec": "gossip", "participation": 0.5}),
    ("`+ churn`", {"exec": "gossip", "churn": True}),
    ("`personalization`", {"personalization": True}),
    ("`topology`", {"topology": True}),
    ("`sweep()`", {"sweep": True}),
)


def _cell_supported(solver, backend: str, probe: dict[str, Any]) -> bool:
    from repro.core.gossip import ChurnSchedule
    from repro.core.graph import TopologySchedule
    from repro.core.personalize import Personalization

    from repro.api.config import FitConfig

    kw: dict[str, Any] = {"backend": backend,
                          "algorithm": solver.name,
                          "exec": probe.get("exec", "sync")}
    if probe.get("participation"):
        kw["participation"] = probe["participation"]
    if probe.get("churn"):
        kw["churn"] = ChurnSchedule(leave=((2, 0),))
    if probe.get("personalization"):
        kw["personalization"] = Personalization()
    if probe.get("topology"):
        kw["topology"] = TopologySchedule.circulant_cycle(8, [(1,)])
    streaming = getattr(solver, "streaming", False)
    try:
        config = FitConfig(**kw)
        if probe.get("sweep"):
            check_sweep(config, solver)
        elif streaming:
            check_stream(config, solver)
        else:
            check_fit(config, solver)
    except ValueError:
        return False
    return True


def support_matrix() -> str:
    """The solver × backend × exec/feature matrix as markdown, each cell
    decided by the admission rules themselves (✅ = the drivers accept the
    combination, — = they reject it with a named alternative)."""
    from repro.api.config import BACKENDS
    from repro.api.registry import get_solver, list_solvers

    header = ("| solver | backend | "
              + " | ".join(label for label, _ in _FEATURE_PROBES) + " |")
    sep = "|---|---|" + "---|" * len(_FEATURE_PROBES)
    lines = [BEGIN_MARK, "", header, sep]
    for name in list_solvers():
        solver = get_solver(name)
        streaming = getattr(solver, "streaming", False)
        backends = (getattr(solver, "stream_backends", ())
                    if streaming else solver.backends)
        driver = "`fit_stream`" if streaming else "`fit`"
        for backend in BACKENDS:
            if backend not in backends:
                continue
            cells = " | ".join(
                "✅" if _cell_supported(solver, backend, probe) else "—"
                for _, probe in _FEATURE_PROBES)
            lines.append(f"| `{name}` ({driver}) | `{backend}` "
                         f"| {cells} |")
    lines += ["", END_MARK]
    return "\n".join(lines)


def update_readme(path: str) -> bool:
    """Rewrite the README block between the support-matrix markers from
    the table; returns True when the file changed."""
    with open(path) as f:
        text = f.read()
    start = text.index(BEGIN_MARK)
    end = text.index(END_MARK) + len(END_MARK)
    new = text[:start] + support_matrix() + text[end:]
    if new == text:
        return False
    with open(path, "w") as f:
        f.write(new)
    return True


if __name__ == "__main__":
    import os

    readme = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "..", "..", "..", "README.md")
    changed = update_readme(os.path.normpath(readme))
    print("README support matrix "
          + ("updated" if changed else "already in sync"))
