"""repro.api — the canonical entry point for every algorithm in the repo.

One registry, one `fit()`, pluggable backends — and the deployment half:
`FitResult.to_model()` exports a `KernelModel` with `predict` / `evaluate`
/ `save` / `load`, `sweep()` fits a whole policy grid in one vmapped scan,
and `repro.serve.KernelServer` microbatches scoring traffic over a mesh.

    from repro.api import Censor, Chain, Drop, FitConfig, Quantize, fit

    result = fit(FitConfig(
        algorithm="coke", num_iters=500,
        comm=Chain([Censor(v=0.5, mu=0.97),   # h(k) = v mu^k (the paper)
                    Quantize(bits=4),         # QC-ODKLA-style innovations
                    Drop(p=0.05)])))          # unreliable links
    result.bits                             # per-iteration cumulative bits
    model = result.to_model()
    y_hat = model.predict(x_new)            # ref or fused (Pallas) backend
    model.save("artifacts/coke")

Algorithms (see `list_solvers()`): dkla, coke, cta, ridge_oracle, and the
streaming family online_dkla / online_coke / qc_odkla — driven over
per-agent minibatch streams by `fit_stream(config)` (build one with
`build_stream`, or hand `KernelModel.partial_fit` fresh traffic to
online-refine a batch-trained model). Backends: "simulator" (in-process
reference), "spmd" (repro.distributed.consensus ring runtime), "fused"
(spmd + Pallas `coke_update` kernel). The legacy drivers `core.admm.run` /
`core.cta.run` remain as deprecation shims.

Execution semantics: `FitConfig(exec="gossip", participation=0.25)` runs
the asynchronous gossip engine — per iteration only a sampled subset of
agents computes and broadcasts (sleepers hold state, pay zero bits, and
serve stale values to neighbors), with `ChurnSchedule` scripting straggler
slowdowns and agent join/leave on the simulator backend. participation=1.0
reproduces exec="sync" (see repro.core.gossip).

Personalization: `FitConfig(personalization=Personalization(k=3))` learns
a sparse mutual-top-k collaboration graph from theta affinities alongside
the ADMM/streaming iterations, so agents with heterogeneous (non-IID)
data keep distinct models and collaborate only with their cluster (see
repro.core.personalize; `result.to_models()` exports one KernelModel per
agent, `data.synthetic.heterogeneous` generates the clustered workload).

The training-loop integration (consensus data-parallelism for deep nets)
is re-exported here too, so downstream scripts need only this surface.
"""
from repro.api.config import (BACKENDS, FitConfig,  # noqa: F401
                              FitResult, SolveContext)
from repro.api.fit import fit, fit_stream  # noqa: F401
from repro.api.model import (KernelModel, PREDICT_BACKENDS,  # noqa: F401
                             predict)
from repro.api.problems import (BuiltProblem, BuiltStream,  # noqa: F401
                                StreamProblem, build_problem, build_stream,
                                stream_from_arrays)
from repro.api.registry import (Solver, get_solver,  # noqa: F401
                                list_solvers, register_solver)
from repro.api.sweep import SweepResult, sweep  # noqa: F401

# the algorithm/problem vocabulary examples and benchmarks need, so they
# can be written against repro.api alone
from repro.configs.coke_krr import KRRConfig, PAPER_SETUPS  # noqa: F401
from repro.core.admm import Problem, make_problem  # noqa: F401
from repro.core.censor import CensorSchedule  # noqa: F401
from repro.core.comm import (Censor, Chain, CommState,  # noqa: F401
                             Drop, Quantize)
from repro.core.gossip import (ChurnSchedule, GossipPlan,  # noqa: F401
                               NeighborTable)
from repro.core.graph import TopologySchedule  # noqa: F401
from repro.core.personalize import (Personalization,  # noqa: F401
                                    graph_recovery)
from repro.core.ridge import rf_ridge  # noqa: F401
from repro.data.synthetic import heterogeneous  # noqa: F401

# consensus data-parallel training surface (deep-net workloads)
from repro.distributed.consensus import ConsensusConfig  # noqa: F401
from repro.optim.optimizers import OptConfig  # noqa: F401
from repro.train.steps import agent_batch, make_train_step  # noqa: F401
