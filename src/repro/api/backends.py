"""Backend routing for `fit()`: the same FitConfig runs on

  simulator — the in-process reference (all agents as a leading batch axis,
              neighbor exchange = adjacency matmul); driven by the Solver
              protocol directly from repro.api.fit.
  spmd      — the repro.distributed.consensus runtime: agent axis sharded
              over the mesh, neighbor exchange as jnp.roll (lowers to
              collective-permute), inexact one-step primal update.
  fused     — the Pallas hot path. On megakernel-admissible configs
              (dkla/coke, gradient primal, quadratic loss, static ring,
              no mesh/personalization) the whole ADMM iteration runs as
              ONE `coke_megastep` pallas_call substituted into the
              `core.step.StepProgram` primal+exchange stages, bit-equal
              to the unfused blockwise StepProgram reference
              (`kernels.coke_update.ref.coke_megastep_ref`). Everything
              else falls back to spmd with the augmented-gradient +
              censor-norm combine in the `coke_update` kernel. Kernels
              compile on TPU/GPU and interpret on CPU
              (repro.kernels.runtime.resolve_interpret).

The spmd/fused backends require a circulant graph family — the topology the
ring collectives implement — and are validated against the problem's
adjacency so a mismatched FitConfig fails loudly instead of silently
solving a different consensus problem.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig, SolveContext
from repro.api.registry import Solver
from repro.api.solvers import (_per_agent_mse, _stacked_metrics,
                               _uncompressed_bits)
from repro.core import admm
from repro.core import comm as comm_mod
from repro.core import gossip as gossip_mod
from repro.core import losses as losses_mod
from repro.core import personalize as personalize_mod
from repro.core import step as step_mod
from repro.core.admm import Problem
from repro.core.graph import circulant
from repro.distributed import consensus as cns
from repro.distributed.sharding import shard_features, shard_problem
from repro.kernels.coke_update.coke_update import coke_megastep
from repro.kernels.coke_update.ref import coke_megastep_ref
from repro.optim.optimizers import OptConfig

#: debug/bench knob: route megakernel-admissible fused fits through the
#: blockwise unfused StepProgram reference (`coke_megastep_ref`) instead
#: of the pallas_call. Bit-identical by contract — the conformance tests
#: and `benchmarks/fused_bench.py` flip this to pin/time the two paths.
_MEGASTEP_USE_KERNEL = True


def _validate_topology(problem: Problem, offsets: tuple[int, ...]) -> None:
    N = problem.num_agents
    want = circulant(N, offsets).adjacency
    have = np.asarray(problem.adjacency)
    if not np.array_equal(have, want):
        raise ValueError(
            "spmd/fused backends implement circulant topologies (ring "
            f"collectives with offsets {offsets}); the problem's adjacency "
            "does not match — build it with FitConfig(graph='ring'/"
            "'circulant') or use backend='simulator'")


def _validate_schedule(problem: Problem, topology) -> None:
    """Each scheduled graph must be the circulant its offsets claim —
    otherwise the ring runtime silently solves a different consensus
    problem than the simulator."""
    N = problem.num_agents
    for i, off in enumerate(topology.offsets):
        off = tuple(off)
        seen = set()
        for o in off:
            pair = frozenset(((o % N), (-o) % N))
            if (2 * o) % N == 0 or pair in seen:
                raise ValueError(
                    f"offset {o} is degenerate on N={N} agents (the ±{o} "
                    "permutes alias the same neighbor, double-counting it "
                    "in the ring runtime); choose offsets with 2*o % N != 0")
            seen.add(pair)
        want = circulant(N, off).adjacency
        have = np.asarray(topology.adjacencies[i])
        if not np.array_equal(have, want):
            raise ValueError(
                f"topology schedule graph {i} does not match the circulant "
                f"with offsets {tuple(off)}; build the schedule with "
                "TopologySchedule.circulant_cycle or use "
                "backend='simulator'")


def _local_grads(problem: Problem, theta: jax.Array) -> jax.Array:
    N = problem.num_agents

    def g1(theta_i, phi, y):
        return jax.grad(losses_mod.local_empirical_risk)(
            theta_i, phi, y, problem.lam / N, problem.loss)

    return jax.vmap(g1)(theta, problem.feats, problem.labels)


def _resolve_consensus_primal(config: FitConfig, problem: Problem,
                              strategy: str) -> str:
    """The primal mode the distributed runtimes execute. "auto" keeps the
    legacy one-step inexact update up to the big-D crossover (bit-parity
    with existing spmd/fused trajectories), then switches to the exact
    matrix-free CG solve — the regime where one gradient step per round is
    both slow to converge and the only thing that used to exist. Explicit
    "cholesky" is rejected: these backends never materialize (D, D)."""
    if strategy not in ("dkla", "coke", "coke_et"):
        return "gradient"
    if config.primal == "cholesky":
        raise ValueError(
            "the spmd/fused backends never materialize per-agent (D, D) "
            "factors; use primal='cg' (exact, matrix-free) or "
            "'gradient'/'auto' (one-step inexact)")
    if config.primal == "cg":
        return admm.resolve_primal("cg", problem.feature_dim, problem.loss)
    if (config.primal == "auto" and problem.loss == "quadratic"
            and problem.feature_dim > admm.CG_CROSSOVER_DIM):
        return "cg"
    return "gradient"


def _cg_primal_solve(problem: Problem, cg_tol: float, cg_maxiter: int):
    """Adapt the matrix-free CG solve of (21a) to the consensus runtime's
    agent-stacked tree form: the runtime hands over (params, theta_hat,
    gamma, summed neighbor theta_hat, degree) and gets the exact primal
    back — no (D, D) array, warm-started from the previous iterate.

    Call this with the TRACED problem inside the jitted chunk — closing
    over a concrete Problem would embed feats (268 MB at D=65536) as a
    trace-time constant and, passed as a jit static arg, the fresh closure
    would miss the compilation cache on every fit()."""
    def solve(params, theta_hat, gamma, nbr_sum, deg):
        deg_vec = jnp.broadcast_to(
            jnp.asarray(deg, problem.feats.dtype),
            (problem.num_agents,))
        theta = admm._primal_cg(
            problem, gamma["theta"], theta_hat["theta"], nbr_sum["theta"],
            deg_vec, theta0=params["theta"],
            tol=cg_tol, maxiter=cg_maxiter)
        return {"theta": theta.astype(params["theta"].dtype)}

    return solve


@partial(jax.jit, static_argnames=("ccfg", "opt_cfg", "num_iters",
                                   "primal_mode", "cg_tol", "cg_maxiter",
                                   "pz_metric"))
def _consensus_chunk(problem, params, cstate, oracle, comm, gossip,
                     personalize, ccfg, opt_cfg, num_iters,
                     primal_mode=None, cg_tol=1e-8, cg_maxiter=64,
                     pz_metric=False):
    # the exact primal is built HERE, from the traced problem argument:
    # the static jit key stays the value-hashable (ccfg, opt_cfg, mode,
    # tol, maxiter) tuple, so repeated fits share one compilation
    primal_solve = (_cg_primal_solve(problem, cg_tol, cg_maxiter)
                    if primal_mode == "cg" else None)
    n_agents = problem.num_agents

    def body(carry, _):
        params, cstate = carry
        # gossip: the round's participation mask, drawn from the SAME
        # CommState key + iteration fold as the simulator path — both
        # backends sample identical wake-up schedules, so comms/bits
        # histories agree exactly across backends. Under churn, the same
        # alive/joined masks as the simulator's table_view thread into
        # the ring exchange (alive-weighted degrees + masked permutes).
        participate = alive = joined = None
        if gossip is not None:
            k = cstate["step"] + 1
            if gossip.has_churn:
                alive = gossip.alive_at(k)
                joined = alive & ~gossip.alive_at(k - 1)
            participate = gossip_mod.participation_mask(
                cstate["comm"].key, k, n_agents, gossip, alive)
        # personalization: refresh the learned graph if due (same cadence
        # and affinity computation as the simulator — graphs match
        # bit-for-bit), then run the round dense on it
        adjacency = None
        if personalize is not None:
            adjacency = personalize_mod.maybe_update(
                personalize, params["theta"], cstate["step"] + 1,
                cstate["adjacency"])
        if primal_solve is None:
            grads = {"theta": _local_grads(problem, params["theta"])}
        else:  # exact primal: the local gradient is folded into the solve
            grads = {"theta": jnp.zeros_like(params["theta"])}
        params, cstate, extra = cns.consensus_update(
            ccfg, opt_cfg, params, grads, cstate, comm=comm,
            primal_solve=primal_solve, participate=participate,
            adjacency=adjacency, alive=alive, joined=joined)
        if personalize is not None:
            cstate = dict(cstate, adjacency=adjacency)
        bits = extra.get("bits")
        if bits is None:  # policy-unaware strategy (cta): full precision
            bits = _uncompressed_bits(problem, cstate["comms"])
        m = _stacked_metrics(problem, params["theta"], cstate["comms"],
                             bits)
        m.update(extra)
        if pz_metric:  # key-parity with the simulator personalized path
            m["per_agent_mse"] = _per_agent_mse(problem, params["theta"])
        if oracle is not None:
            m["dist_to_oracle"] = jnp.max(jnp.linalg.norm(
                params["theta"] - oracle, axis=-1))
        return (params, cstate), m

    (params, cstate), hist = jax.lax.scan(body, (params, cstate), None,
                                          length=num_iters)
    return (params, cstate), hist


class _FusedCarry(NamedTuple):
    """core.step.run_step carry for the megakernel path — the six
    canonical fields as bare (N, D) arrays (the consensus-state dicts are
    unwrapped at the chunk boundary and rewrapped after the scan)."""
    theta: jax.Array
    theta_hat: jax.Array
    gamma: jax.Array
    step: jax.Array
    comms: jax.Array
    comm: object


@partial(jax.jit, static_argnames=("ccfg", "num_iters", "lr",
                                   "use_kernel"))
def _megastep_chunk(problem, params, cstate, oracle, comm, gossip, ccfg,
                    num_iters, lr, use_kernel=True):
    """The fused-backend megakernel chunk: one `coke_megastep`
    pallas_call per iteration, substituted into the StepProgram
    primal+exchange stages (`primal_owns_exchange=True` — the kernel
    reads the ring-rolled neighbor rows itself, so `run_step` skips the
    pre-primal permute). With use_kernel=False the same program runs the
    blockwise unfused reference — bitwise-identical histories, which is
    the megakernel's conformance contract.

    Metric keys match `_consensus_chunk` exactly (train_mse / comms /
    consensus_gap / bits / send_frac [+ dist_to_oracle]), so every
    cross-backend history comparison works unchanged. The circulant
    neighbor caches (nbr_left/nbr_right) in the consensus state are
    carried untouched: the kernel re-reads theta_hat rows each step
    instead of consuming the cached dual-update fetch."""
    chain = (ccfg.comm_chain() if comm is None
             else comm_mod.as_chain(comm))
    n_agents = problem.num_agents
    offsets = ccfg.offsets
    fn = coke_megastep if use_kernel else coke_megastep_ref

    def nbr_sum(x):
        out = None
        for o in offsets:
            both = jnp.roll(x, o, axis=0) + jnp.roll(x, -o, axis=0)
            out = both if out is None else out + both
        return out

    view = step_mod.GraphView(
        deg=jnp.full((n_agents,), ccfg.degree, jnp.float32),
        nbr_sum=nbr_sum)

    def primal(k, g, theta0, theta_hat0, gamma0, nbr_hat):
        theta_new, _xi_sq = fn(
            theta0, theta_hat0, gamma0, problem.feats, problem.labels,
            rho=ccfg.rho, lam=problem.lam, lr=lr, offsets=offsets)
        # _xi_sq — the kernel's fused censor-norm partial sums,
        # ||theta_new - theta_hat||^2 — is validated against the censor
        # policy in tests; the portable `chain.apply` recomputes the
        # norm so the decision bits stay identical on every backend.
        return theta_new.astype(theta0.dtype), {}

    program = step_mod.StepProgram(
        chain=chain, rho=ccfg.rho, exchange=lambda state, k: view,
        primal=primal,
        comm_decide=(None if gossip is None
                     else step_mod.sampled_stage(gossip)),
        primal_owns_exchange=True)

    def body(carry, _):
        st, opt = carry
        new_st, _ = step_mod.run_step(program, st)
        # the optimizer step is fused into the kernel (theta - lr*g_aug,
        # bitwise sgd); keep the carried slot's step count in sync
        if isinstance(opt, dict) and "count" in opt:
            opt = dict(opt, count=opt["count"] + 1)
        bits = jnp.sum(new_st.comm.bits)
        m = _stacked_metrics(problem, new_st.theta, new_st.comms, bits)
        m["send_frac"] = ((new_st.comms - st.comms).astype(jnp.float32)
                          / n_agents)
        m["bits"] = bits
        if oracle is not None:
            m["dist_to_oracle"] = jnp.max(jnp.linalg.norm(
                new_st.theta - oracle, axis=-1))
        return (new_st, opt), m

    st0 = _FusedCarry(
        theta=params["theta"], theta_hat=cstate["theta_hat"]["theta"],
        gamma=cstate["gamma"]["theta"], step=cstate["step"],
        comms=cstate["comms"], comm=cstate["comm"])
    (st, opt), hist = jax.lax.scan(body, (st0, cstate["opt"]), None,
                                   length=num_iters)
    new_params = {"theta": st.theta}
    new_cstate = dict(cstate, opt=opt, step=st.step, comms=st.comms,
                      comm=st.comm, theta_hat={"theta": st.theta_hat},
                      gamma={"theta": st.gamma})
    return (new_params, new_cstate), hist


@partial(jax.jit, static_argnames=("ccfg", "num_iters", "lam", "lr",
                                   "eta"))
def _stream_chunk(stream, params, cstate, comm, gossip, personalize,
                  ccfg, num_iters, lam, lr, eta):
    n_agents = stream.num_agents

    def body(carry, _):
        params, cstate = carry
        participate = alive = joined = None
        if gossip is not None:  # same draw/masks as the simulator
            k = cstate["step"] + 1
            if gossip.has_churn:
                alive = gossip.alive_at(k)
                joined = alive & ~gossip.alive_at(k - 1)
            participate = gossip_mod.participation_mask(
                cstate["comm"].key, k, n_agents, gossip, alive)
        adjacency = None
        if personalize is not None:  # same refresh as the simulator
            adjacency = personalize_mod.maybe_update(
                personalize, params["theta"], cstate["step"] + 1,
                cstate["adjacency"])
        feats, labels = stream.round_batch(cstate["step"])
        params, cstate, extra = cns.stream_update(
            ccfg, params, cstate, feats, labels,
            lam=lam, lr=lr, eta=eta, comm=comm, participate=participate,
            adjacency=adjacency, alive=alive, joined=joined)
        if personalize is not None:
            cstate = dict(cstate, adjacency=adjacency)
        # exactly the simulator's _stream_metrics keys — streaming
        # histories are key-identical across backends, so the conformance
        # harness can compare any pair with exact="*"
        m = {"train_mse": extra["instant_mse"],
             "instant_mse": extra["instant_mse"],
             "comms": cstate["comms"],
             "consensus_gap": cns.consensus_gap(params),
             "bits": extra["bits"]}
        return (params, cstate), m

    return jax.lax.scan(body, (params, cstate), None, length=num_iters)


def stream_consensus_runner(config: FitConfig, solver: Solver, stream,
                            ctx: SolveContext, theta0=None):
    """-> (carry0, chunk_fn, theta_fn) for fit_stream's spmd backend: the
    ring runtime's `stream_update` (collective-permute neighbor exchange,
    shared `core.comm` decision code) over the StreamProblem's rounds.
    Requires the circulant graph family, like the batch consensus path —
    personalized runs included: their warmup phase executes the exact
    ring-permute program before the learned dense graph takes over."""
    offsets = config.graph_offsets
    _validate_topology(stream, offsets)

    # stream_update reads only rho / offsets / degree from the config —
    # strategy and the CTA mix_weight play no role on the streaming path
    ccfg = cns.ConsensusConfig(rho=stream.rho, offsets=offsets)

    # the solver's policy view of the configured chain (online_dkla strips
    # censor thresholds), traced into the compiled chunk
    chain = solver._policy(ctx)
    eta = solver._eta(ctx)

    N, D = stream.num_agents, stream.feature_dim
    if theta0 is None:
        theta = jnp.zeros((N, D), stream.feats.dtype)
    else:
        theta = jnp.broadcast_to(
            jnp.asarray(theta0, stream.feats.dtype), (N, D))
    params = {"theta": theta}
    cstate = cns.init_stream_state(ccfg, theta, comm=chain)
    pz_live = ctx.personalization is not None and not ctx.pz_warmup
    if pz_live:
        cstate["adjacency"] = jnp.asarray(stream.adjacency, jnp.float32)
    personalize = ctx.personalization if pz_live else None

    gplan = ctx.gossip if ctx.exec == "gossip" else None

    def chunk_fn(carry, n):
        params, cstate = carry
        return _stream_chunk(stream, params, cstate, chain, gplan,
                             personalize, ccfg=ccfg, num_iters=n,
                             lam=stream.lam, lr=ctx.online_lr, eta=eta)

    return (params, cstate), chunk_fn, lambda carry: carry[0]["theta"]


def consensus_runner(config: FitConfig, solver: Solver, problem: Problem,
                     ctx: SolveContext, oracle: jax.Array | None,
                     mesh=None):
    """-> (carry0, chunk_fn, theta_fn) for the spmd / fused backends.

    mesh — optional jax mesh; when given, the Problem and the consensus
    carry (theta / theta_hat / gamma / neighbor caches) are placed with the
    feature dim sharded over the mesh's "model" axis and the agent dim over
    its batch axes (distributed.sharding.feature_spec), so each device
    holds (N, D/shards) slices and the censor norm reduces with one psum.
    """
    strategy = solver.consensus_strategy
    if strategy is None:
        raise ValueError(
            f"solver {solver.name!r} has no distributed strategy; "
            "use backend='simulator'")
    primal_mode = _resolve_consensus_primal(config, problem, strategy)
    offset_schedule = None
    if config.topology is not None:
        offset_schedule = config.topology.offsets
        if offset_schedule is None:
            raise ValueError(
                "the spmd/fused backends implement circulant topologies; "
                "give the TopologySchedule its per-graph `offsets` (e.g. "
                "TopologySchedule.circulant_cycle) or use "
                "backend='simulator'")
        _validate_schedule(problem, config.topology)
        offsets = offset_schedule[0]
    else:
        offsets = config.graph_offsets
        _validate_topology(problem, offsets)

    v, mu = config.resolved_censor
    k = len(offsets)
    ccfg = cns.ConsensusConfig(
        strategy=strategy, rho=problem.rho, censor_v=v, censor_mu=mu,
        offsets=offsets, offset_schedule=offset_schedule,
        # per-neighbor Metropolis weight on a 2k-regular circulant
        mix_weight=k / (2.0 * k + 1.0),
        use_fused_kernel=config.backend == "fused")
    lr = ctx.cta_lr if strategy == "cta" else ctx.inner_lr
    opt_cfg = OptConfig(kind="sgd", lr=lr)

    # the solver's policy view of the configured chain (e.g. DKLA strips
    # the censor thresholds), traced into the compiled chunk
    chain = (solver._policy(ctx) if getattr(solver, "comm_aware", False)
             else None)

    N, _, D = problem.feats.shape
    params = {"theta": jnp.zeros((N, D), problem.feats.dtype)}
    cstate = cns.init_consensus_state(ccfg, opt_cfg, params, comm=chain)

    if mesh is not None:
        # the sharded problem flows into the chunk as an argument, so the
        # CG matvec built inside runs on the (N, D/shards) slices
        problem = shard_problem(problem, mesh)
        params = shard_features(params, mesh, N)
        cstate = shard_features(cstate, mesh, N)

    # personalized live phase: the learned (N, N) graph rides in the
    # carry, added after the feature-dim placement above (it has no
    # feature dim to shard). The warmup phase runs the exact static
    # program — no adjacency in the carry, no graph machinery traced.
    pz_live = ctx.personalization is not None and not ctx.pz_warmup
    if pz_live:
        cstate["adjacency"] = jnp.asarray(problem.adjacency, jnp.float32)
    personalize = ctx.personalization if pz_live else None
    pz_metric = ctx.personalization is not None

    gplan = ctx.gossip if ctx.exec == "gossip" else None

    # megakernel admission: one pallas_call per iteration, substituted
    # into the StepProgram primal+exchange stages. The gate mirrors what
    # the kernel bakes in statically: a fixed circulant (no schedule, no
    # learned graph, no churn — churn-fused is already rejected by the
    # capabilities table), the one-step gradient primal on the quadratic
    # loss, and an unsharded carry. Everything outside falls back to the
    # legacy spmd+coke_update path below, bit-identical to before.
    use_mega = (config.backend == "fused"
                and strategy in ("dkla", "coke")
                and primal_mode == "gradient"
                and problem.loss == "quadratic"
                and offset_schedule is None
                and mesh is None
                and ctx.personalization is None)
    if use_mega:
        def mega_chunk_fn(carry, n):
            params, cstate = carry
            return _megastep_chunk(problem, params, cstate, oracle,
                                   chain, gplan, ccfg=ccfg, num_iters=n,
                                   lr=lr,
                                   use_kernel=_MEGASTEP_USE_KERNEL)
        return (params, cstate), mega_chunk_fn, \
            lambda carry: carry[0]["theta"]

    def chunk_fn(carry, n):
        params, cstate = carry
        return _consensus_chunk(problem, params, cstate, oracle, chain,
                                gplan, personalize, ccfg=ccfg,
                                opt_cfg=opt_cfg, num_iters=n,
                                primal_mode=primal_mode,
                                cg_tol=ctx.cg_tol,
                                cg_maxiter=ctx.cg_maxiter,
                                pz_metric=pz_metric)

    return (params, cstate), chunk_fn, lambda carry: carry[0]["theta"]
