"""`fit(config) -> FitResult` — the one driver for every algorithm/backend
— and its streaming sibling `fit_stream(config) -> FitResult` for the
online family over per-agent minibatch streams.

The driver owns the `lax.scan` iteration loop, the per-iteration metric
recording (train MSE, cumulative transmissions, consensus gap, optional
distance-to-oracle; for streams the regret-protocol instantaneous MSE and
cumulative bits), and optional chunked host callbacks for streaming
progress. Algorithm math lives in the registered solvers; distributed
execution lives in repro.api.backends.

Compilation contract: the censor thresholds (v, mu) enter the compiled loop
as traced array data, so a sweep over censor schedules — the paper's tuning
protocol — reuses ONE compiled fit loop per (problem shape, algorithm,
num_iters) instead of retracing per float pair as the legacy
`core.admm.run(schedule-as-static)` entry point did.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.api.backends import consensus_runner, stream_consensus_runner
from repro.api.capabilities import check_fit, check_stream
from repro.api.config import FitConfig, FitResult, SolveContext
from repro.api.problems import StreamProblem, build_problem, build_stream
from repro.api.registry import Solver, get_solver
from repro.core import ridge
from repro.core.admm import Problem

ProgressCb = Callable[[int, dict], None]


@partial(jax.jit, static_argnames=("solver", "num_iters"))
def _simulator_chunk(solver: Solver, problem: Problem, ctx: SolveContext,
                     host_aux, state, oracle, num_iters: int):
    aux = solver.prepare_traced(problem, ctx, host_aux)

    def body(state, _):
        state = solver.step(problem, ctx, aux, state)
        m = solver.metrics(problem, ctx, aux, state)
        if oracle is not None:
            m["dist_to_oracle"] = jnp.max(jnp.linalg.norm(
                solver.theta_of(state) - oracle, axis=-1))
        return state, m

    return jax.lax.scan(body, state, None, length=num_iters)


def _simulator_runner(config: FitConfig, solver: Solver, problem: Problem,
                      ctx: SolveContext, oracle, mesh=None):
    host_aux = solver.prepare_host(problem, ctx)
    state0 = solver.init_state(problem, ctx)
    if mesh is not None:
        from repro.distributed.sharding import shard_features, shard_problem

        problem = shard_problem(problem, mesh)
        state0 = shard_features(state0, mesh, problem.num_agents)

    def chunk_fn(state, n):
        return _simulator_chunk(solver, problem, ctx, host_aux, state,
                                oracle, num_iters=n)

    return state0, chunk_fn, solver.theta_of


def _chunked_scan(chunk_fn, carry, num_iters: int, chunk_size: int | None,
                  progress_cb: ProgressCb | None):
    """Run the scan in host-visible chunks; with chunk_size=None this is a
    single scan, trajectory-identical to the legacy monolithic drivers."""
    hists, done = [], 0
    while True:
        n = num_iters - done if chunk_size is None else min(
            chunk_size, num_iters - done)
        carry, h = chunk_fn(carry, n)  # n == 0 still yields (0,)-histories
        done += n
        hists.append(h)
        if progress_cb is not None and n > 0:
            progress_cb(done, jax.tree.map(lambda a: a[-1], h))
        if done >= num_iters:
            break
    if len(hists) == 1:
        return carry, hists[0]
    return carry, jax.tree.map(lambda *xs: jnp.concatenate(xs), *hists)


def _pz_enter_live(carry, adjacency):
    """Attach the starting adjacency when a personalized fit crosses the
    warmup -> live boundary: the live program's carry holds the learned
    graph as loop state, the warmup program's carry does not."""
    from repro.api.solvers import OnlineFitState
    from repro.core.admm import COKEState
    from repro.core.personalize import PersonalizedState

    A0 = jnp.asarray(adjacency, jnp.float32)
    if isinstance(carry, OnlineFitState):
        return carry._replace(adjacency=A0)
    if isinstance(carry, COKEState):
        return PersonalizedState(carry, A0)
    params, cstate = carry  # spmd/fused (params, cstate) carry
    return params, dict(cstate, adjacency=A0)


def phase_plan(ctx: SolveContext, num_iters: int, adjacency):
    """Decompose one fit into its phased program: a tuple of
    (phase_ctx, num_iters, enter_fn) where enter_fn (None on the first
    phase) transforms the carry at the phase boundary. Ordinary fits are
    one phase; a personalized fit with warmup > 0 is the two-phase
    warmup -> live program. The plan is the *data* both drivers share:
    fit()/fit_stream() walk it through the chunked host loop, and
    sweep()'s vmapped scan replays the same phases inside one compiled
    program — which is what makes personalization-aware sweeps possible.

    Iterations 1..warmup run a SEPARATE compiled program
    (ctx.pz_warmup=True) that takes the exact static-consensus code path —
    no graph machinery in its trace — so the warmup prefix is
    bit-identical to a personalization=None run by construction rather
    than by XLA fusion luck (a lax.cond in the scan body measurably
    perturbs float rounding). A zero-length live phase (warmup >=
    num_iters) still applies its carry transform, so the final state
    carries the adjacency either way."""
    if ctx.personalization is None:
        return ((ctx, num_iters, None),)
    W = min(int(ctx.personalization.warmup), num_iters)
    if W <= 0:
        return ((ctx, num_iters, None),)
    ctx_warm = dataclasses.replace(ctx, pz_warmup=True)
    return ((ctx_warm, W, None),
            (ctx, num_iters - W,
             lambda carry: _pz_enter_live(carry, adjacency)))


def _phased_runner(make_runner, plan):
    """Drive a phase_plan through the chunked host loop: one runner per
    phase, carries handed across boundaries through the plan's enter
    transforms, histories concatenated (phase metrics share one key set —
    the key-parity contract the personalized metrics keep)."""
    if len(plan) == 1 and plan[0][2] is None:
        return make_runner(plan[0][0])
    runners = [make_runner(c) for c, _, _ in plan]
    ends, total = [], 0
    for _, n, _ in plan:
        total += n
        ends.append(total)
    pos = {"done": 0, "phase": 0}

    def chunk_fn(carry, n):
        hists, left = [], n
        while True:
            i = pos["phase"]
            m = min(left, ends[i] - pos["done"])
            carry, h = runners[i][1](carry, m)
            pos["done"] += m
            left -= m
            hists.append(h)
            # cross every boundary reached — including with 0 iterations
            # left, so a final chunk still applies the carry transform
            while (pos["phase"] < len(ends) - 1
                   and pos["done"] >= ends[pos["phase"]]):
                pos["phase"] += 1
                enter = plan[pos["phase"]][2]
                if enter is not None:
                    carry = enter(carry)
            if left == 0:
                break
        if len(hists) == 1:
            return carry, hists[0]
        return carry, jax.tree.map(lambda *xs: jnp.concatenate(xs), *hists)

    return runners[0][0], chunk_fn, runners[-1][2]


def fit(config: FitConfig, problem: Problem | None = None, *,
        progress_cb: ProgressCb | None = None,
        oracle: jax.Array | None = None,
        mesh=None) -> FitResult:
    """Run `config.algorithm` on `config.backend` and record the paper's
    evaluation trajectories.

    problem     — an existing `admm.Problem`; None builds one from
                  config.krr / config.graph (see repro.api.build_problem).
    progress_cb — called as progress_cb(iters_done, last_metrics) after
                  every `config.chunk_size` iterations.
    oracle      — theta* (D,) for per-iteration distance-to-oracle; computed
                  via the closed form when `config.record_oracle_distance`
                  is set and no oracle is passed.
    mesh        — optional jax mesh for the big-D path: the problem's
                  feature dim shards over the mesh's "model" axis and the
                  agent dim over its batch axes (theta/theta_hat/gamma live
                  as (N, D/shards) per device; see
                  distributed.sharding.feature_spec). Pair with
                  primal="cg" — a sharded (D, D) Cholesky factor would
                  defeat the point.
    """
    if isinstance(problem, StreamProblem):
        raise ValueError(
            "fit() drives batch problems; run a StreamProblem through "
            "fit_stream(config, stream=...)")
    solver = get_solver(config.algorithm)
    check_fit(config, solver)
    rff_params = None
    if problem is None:
        built = build_problem(config)
        problem, rff_params = built.problem, built.rff_params
    if oracle is None and config.record_oracle_distance:
        oracle = ridge.rf_ridge(problem.feats, problem.labels, problem.lam)
    if config.topology is not None and (
            config.topology.num_agents != problem.num_agents):
        raise ValueError(
            f"topology schedule is over {config.topology.num_agents} "
            f"agents but the problem has {problem.num_agents}")

    ctx = SolveContext.from_config(config, num_agents=problem.num_agents)

    def make_runner(c: SolveContext):
        if config.backend == "simulator":
            return _simulator_runner(config, solver, problem, c, oracle,
                                     mesh=mesh)
        return consensus_runner(config, solver, problem, c, oracle,
                                mesh=mesh)

    carry0, chunk_fn, theta_fn = _phased_runner(
        make_runner, phase_plan(ctx, config.resolved_iters,
                                problem.adjacency))

    carry, history = _chunked_scan(chunk_fn, carry0, config.resolved_iters,
                                   config.chunk_size, progress_cb)
    return FitResult(config=config, state=carry, history=history,
                     theta=theta_fn(carry), rff_params=rff_params)


def fit_stream(config: FitConfig, stream: StreamProblem | None = None, *,
               theta0: jax.Array | None = None,
               progress_cb: ProgressCb | None = None) -> FitResult:
    """Run a streaming solver (`online_dkla` / `online_coke` / `qc_odkla`)
    over a per-agent minibatch stream and record the regret-style history
    (instantaneous pre-update MSE, cumulative comms/bits, consensus gap)
    through the same chunked-scan driver as `fit()`.

    stream      — an existing `StreamProblem`; None builds one from
                  config.krr / config.stream / config.online_batch with
                  one round per iteration (see repro.api.build_stream).
    theta0      — optional warm start: (D,) or (N, D) parameters every
                  agent begins from (theta AND last-broadcast theta_hat) —
                  what `KernelModel.partial_fit` passes.
    progress_cb — as in fit(): called after every config.chunk_size
                  iterations with (iters_done, last_metrics).

    The result deploys exactly like a batch fit: `fit_stream(...)
    .to_model()` yields a `KernelModel` (predict / evaluate / save /
    serve) whose RFF map is the stream's featurization.
    """
    solver = get_solver(config.algorithm)
    check_stream(config, solver)
    rff_params = None
    if stream is None:
        built = build_stream(config)
        stream, rff_params = built.stream, built.rff_params
    if stream.adjacency.shape != (stream.num_agents, stream.num_agents):
        raise ValueError(
            f"stream adjacency {stream.adjacency.shape} does not match its "
            f"{stream.num_agents} agents")

    ctx = SolveContext.from_config(config, num_agents=stream.num_agents)

    def make_runner(c: SolveContext):
        if config.backend == "simulator":
            return _simulator_runner(config, solver, stream, c, None)
        return stream_consensus_runner(config, solver, stream, c,
                                       theta0=theta0)

    carry0, chunk_fn, theta_fn = _phased_runner(
        make_runner, phase_plan(ctx, config.resolved_iters,
                                stream.adjacency))
    if config.backend == "simulator" and theta0 is not None:
        carry0 = solver.warm_start(carry0, theta0)

    carry, history = _chunked_scan(chunk_fn, carry0, config.resolved_iters,
                                   config.chunk_size, progress_cb)
    return FitResult(config=config, state=carry, history=history,
                     theta=theta_fn(carry), rff_params=rff_params)
