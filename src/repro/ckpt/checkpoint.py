"""Sharding-aware npz checkpointing.

Host-gathers every leaf (device_get handles cross-device sharding), stores a
flat path->array npz plus a small JSON manifest (step, tree structure).
Restore rebuilds the pytree and (optionally) re-shards via device_put with
the caller's shardings.

Writes are atomic: both the npz and the manifest land via write-to-temp +
`os.replace`, so a reader (or a crashed writer) never observes a
half-written artifact — the property `serve.ModelRegistry` builds its
versioned publish on.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _write_atomic(path: str, write_fn) -> None:
    """Write through a same-directory temp file + os.replace (atomic on
    POSIX): concurrent readers see the old file or the new one, never a
    torn one."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        write_fn(tmp)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(path: str, tree, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _dump_npz(tmp):
        # np.savez appends .npz when missing — write with the suffix in
        # place so os.replace moves the exact file we wrote
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)

    _write_atomic(path + ".npz", _dump_npz)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {"step": step, "num_leaves": len(leaves),
                "treedef": str(treedef)}

    def _dump_json(tmp):
        with open(tmp, "w") as f:
            json.dump(manifest, f)

    _write_atomic(path + ".json", _dump_json)


def restore(path: str, like, shardings=None):
    """`like`: a pytree with the target structure (arrays or
    ShapeDtypeStructs). Returns (tree, step)."""
    data = np.load(path + ".npz")
    flat_like, _ = _flatten(like)
    restored_flat = {}
    for key, leaf in flat_like.items():
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} != "
                             f"expected {tuple(leaf.shape)}")
        restored_flat[key] = arr.astype(leaf.dtype)
    # rebuild in like's structure
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for leaf_path, _ in paths_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in leaf_path)
        ordered.append(restored_flat[key])
    tree = jax.tree_util.tree_unflatten(treedef, ordered)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    with open(path + ".json") as f:
        step = json.load(f)["step"]
    return tree, step
