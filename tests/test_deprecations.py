"""Deprecation-shim contract for the legacy drivers.

Pins two properties before any future removal: `core.admm.run` and
`core.cta.run` (1) emit a DeprecationWarning that names the replacement,
and (2) still produce bit-identical trajectories and final iterates to
`repro.api.fit` on the same problem. If a future PR deletes the shims,
delete this file with them.
"""
import numpy as np
import pytest

from repro.api import FitConfig, KRRConfig, build_problem, fit
from repro.core import admm, cta
from repro.core.censor import CensorSchedule

BASE = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=30, num_features=8,
                  lam=1e-2, rho=0.5, seed=3),
    algorithm="coke", censor_v=0.4, censor_mu=0.96, num_iters=25)


@pytest.fixture(scope="module")
def built():
    return build_problem(BASE)


def _assert_matches_fit(legacy, result):
    np.testing.assert_array_equal(np.asarray(legacy.train_mse),
                                  np.asarray(result.train_mse))
    np.testing.assert_array_equal(np.asarray(legacy.comms),
                                  np.asarray(result.comms))
    if hasattr(legacy, "consensus_gap"):  # the CTA result records only 2
        np.testing.assert_array_equal(np.asarray(legacy.consensus_gap),
                                      np.asarray(result.consensus_gap))


def test_admm_run_coke_warns_and_matches_fit(built):
    with pytest.warns(DeprecationWarning, match=r"repro\.api\.fit"):
        legacy = admm.run(built.problem, CensorSchedule(0.4, 0.96), 25)
    _assert_matches_fit(legacy, fit(BASE, problem=built.problem))
    np.testing.assert_array_equal(
        np.asarray(legacy.state.theta),
        np.asarray(fit(BASE, problem=built.problem).theta))


def test_admm_run_dkla_warns_and_matches_fit(built):
    with pytest.warns(DeprecationWarning, match=r"repro\.api\.fit"):
        legacy = admm.run(built.problem, admm.dkla_schedule(), 25)
    _assert_matches_fit(legacy,
                        fit(BASE.replace(algorithm="dkla"),
                            problem=built.problem))


def test_cta_run_warns_and_matches_fit(built):
    with pytest.warns(DeprecationWarning, match=r"repro\.api\.fit"):
        legacy = cta.run(built.problem, built.graph, lr=0.85, num_iters=25)
    _assert_matches_fit(legacy,
                        fit(BASE.replace(algorithm="cta", cta_lr=0.85),
                            problem=built.problem))
