"""The benchmark CLI surface: `--only` selection validation (an empty or
whitespace selection must NOT degrade into running every suite), and the
perf gate's $GITHUB_STEP_SUMMARY markdown emission."""
import json

import pytest

from benchmarks import perf_gate
from benchmarks import run as bench_run


# ---------------------------------------------------------------------------
# benchmarks.run --only validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("only", ["", " ", ",", " , ,"],
                         ids=["empty", "space", "comma", "soup"])
def test_only_empty_selection_rejected(only, capsys):
    """`--only ""` (or any all-whitespace/comma selection) exits with a
    usage error instead of silently running ALL suites — a programmatic
    CI invocation with an empty list must not burn the full budget."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", only])
    assert exc.value.code == 2
    assert "no suites" in capsys.readouterr().err


def test_legacy_positional_empty_rejected():
    """The legacy positional spelling gets the same guard."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main([""])
    assert exc.value.code == 2


def test_unknown_suite_rejected(capsys):
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "gossip,nope"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "nope" in err and "gossip" in err  # names the valid choices


# ---------------------------------------------------------------------------
# benchmarks.perf_gate --> $GITHUB_STEP_SUMMARY
# ---------------------------------------------------------------------------

FRESH = {"results": [{"name": "a", "us_per_call": 30.0},
                     {"name": "b", "us_per_call": 10.0},
                     {"name": "c", "us_per_call": 1.0},
                     {"name": "total_wall_s", "us_per_call": 99.0}]}
BASE = {"git_sha": "cafe123", "results": [
    {"name": "a", "us_per_call": 10.0},
    {"name": "b", "us_per_call": 10.0},
    {"name": "d", "us_per_call": 5.0}]}


def test_summary_table_contents():
    md = perf_gate.summary_table(FRESH, BASE, 1.5, "BENCH_gossip.json")
    assert "### perf gate: `BENCH_gossip.json`" in md
    assert "`cafe123`" in md
    assert "| `a` | 10.0 | 30.0 | 3.00x | ❌ FAIL |" in md
    assert "| `b` | 10.0 | 10.0 | 1.00x | ✅ ok |" in md
    assert "🆕 not gated" in md          # fresh-only row c
    assert "gone, not gated" in md       # baseline-only row d
    assert "total_wall_s" not in md      # never gated, never tabled


def test_gate_writes_step_summary(tmp_path, monkeypatch, capsys):
    """main() appends one markdown section per invocation to the file
    named by $GITHUB_STEP_SUMMARY; unset, it writes nothing anywhere."""
    fresh_p = tmp_path / "fresh.json"
    base_p = tmp_path / "BENCH_gossip.json"
    fresh_p.write_text(json.dumps(FRESH))
    base_p.write_text(json.dumps(BASE))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1  # a regressed
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1
    text = summary.read_text()
    assert text.count("### perf gate: `BENCH_gossip.json`") == 2  # appends
    assert "❌ FAIL" in text
    capsys.readouterr()

    monkeypatch.delenv("GITHUB_STEP_SUMMARY")
    summary.unlink()
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1
    assert not summary.exists()          # no-op without the env var
    capsys.readouterr()
