"""The benchmark CLI surface: `--only` selection validation (an empty or
whitespace selection must NOT degrade into running every suite), the
runner's XLA-flags recipe, and the perf gate's $GITHUB_STEP_SUMMARY
markdown emission."""
import json
import os

import pytest

from benchmarks import perf_gate
from benchmarks import run as bench_run


# ---------------------------------------------------------------------------
# benchmarks.run --only validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("only", ["", " ", ",", " , ,"],
                         ids=["empty", "space", "comma", "soup"])
def test_only_empty_selection_rejected(only, capsys):
    """`--only ""` (or any all-whitespace/comma selection) exits with a
    usage error instead of silently running ALL suites — a programmatic
    CI invocation with an empty list must not burn the full budget."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", only])
    assert exc.value.code == 2
    assert "no suites" in capsys.readouterr().err


def test_legacy_positional_empty_rejected():
    """The legacy positional spelling gets the same guard."""
    with pytest.raises(SystemExit) as exc:
        bench_run.main([""])
    assert exc.value.code == 2


def test_unknown_suite_rejected(capsys):
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--only", "gossip,nope"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "nope" in err and "gossip" in err  # names the valid choices


# ---------------------------------------------------------------------------
# benchmarks.run XLA-flags recipe
# ---------------------------------------------------------------------------

def test_xla_flags_recipe(monkeypatch):
    """Caller-set flags win (no duplicate device-count flag — XLA takes
    the LAST occurrence, which would silently override the caller), the
    TPU-only step-marker flag is never added on a CPU host (XLA aborts
    at startup on it), and the TF log level quiets by default."""
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
    monkeypatch.delenv("TF_CPP_MIN_LOG_LEVEL", raising=False)
    monkeypatch.delenv("JAX_PLATFORMS", raising=False)
    bench_run._apply_xla_flags()
    flags = os.environ["XLA_FLAGS"]
    assert flags.count("--xla_force_host_platform_device_count") == 1
    assert "device_count=8" in flags
    if not os.path.exists("/dev/accel0"):  # the suite's CPU containers
        assert "--xla_step_marker_location" not in flags
    assert os.environ["TF_CPP_MIN_LOG_LEVEL"] == "4"


def test_main_does_not_mutate_process_env(monkeypatch, capsys):
    """In-process `main()` calls (this very test suite) must leave
    $XLA_FLAGS alone: the recipe applies at the __main__ entry only.
    A leaked device-count flag would poison subprocesses other tests
    spawn with their own forced device counts."""
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    with pytest.raises(SystemExit):
        bench_run.main(["--only", "nope"])
    capsys.readouterr()
    assert "XLA_FLAGS" not in os.environ


# ---------------------------------------------------------------------------
# benchmarks.perf_gate --> $GITHUB_STEP_SUMMARY
# ---------------------------------------------------------------------------

FRESH = {"results": [{"name": "a", "us_per_call": 30.0},
                     {"name": "b", "us_per_call": 10.0},
                     {"name": "c", "us_per_call": 1.0},
                     {"name": "total_wall_s", "us_per_call": 99.0}]}
BASE = {"git_sha": "cafe123", "results": [
    {"name": "a", "us_per_call": 10.0},
    {"name": "b", "us_per_call": 10.0},
    {"name": "d", "us_per_call": 5.0}]}


def test_summary_table_contents():
    md = perf_gate.summary_table(FRESH, BASE, 1.5, "BENCH_gossip.json")
    assert "### perf gate: `BENCH_gossip.json`" in md
    assert "`cafe123`" in md
    assert "| `a` | 10.0 | 30.0 | 3.00x | ❌ FAIL |" in md
    assert "| `b` | 10.0 | 10.0 | 1.00x | ✅ ok |" in md
    assert "🆕 not gated" in md          # fresh-only row c
    assert "gone, not gated" in md       # baseline-only row d
    assert "total_wall_s" not in md      # never gated, never tabled


MALFORMED = {"results": [
    {"name": "a", "us_per_call": 10.0},       # healthy, gated
    {"name": "zero", "us_per_call": 0.0},     # non-positive -> not gated
    {"name": "neg", "us_per_call": -3.0},     # non-positive -> not gated
    {"name": "nokey"},                        # missing -> not gated
]}
MALFORMED_FRESH = {"results": [
    {"name": "a", "us_per_call": 10.0},
    {"name": "zero", "us_per_call": 5.0},
    {"name": "neg", "us_per_call": 5.0},
    {"name": "nokey", "us_per_call": 5.0},
]}


def test_gate_malformed_baseline_rows_not_gated(capsys):
    """A baseline row with us_per_call <= 0 must NOT produce ratio=inf
    and a spurious FAIL, and a row missing us_per_call must not raise
    KeyError — both are warned as malformed / not gated."""
    failures = perf_gate.gate(MALFORMED_FRESH, MALFORMED, 1.5)
    assert failures == []                      # only `a` gated, 1.00x
    out = capsys.readouterr().out
    assert "ok" in out
    for name, reason in [("zero", "non-positive"), ("neg", "non-positive"),
                         ("nokey", "missing us_per_call")]:
        assert name in out and "not gated" in out
    assert reason  # last reason checked above
    assert "WARN" in out and "non-positive" in out


def test_gate_malformed_fresh_rows_not_gated(capsys):
    """Same guard on the fresh side: a crashed bench emitting 0 us must
    not silently pass as 0.00x NOR fail — it is simply not gated."""
    failures = perf_gate.gate(MALFORMED, MALFORMED_FRESH, 1.5)
    assert failures == []
    out = capsys.readouterr().out
    assert out.count("WARN") == 3


def test_summary_table_malformed_rows():
    md = perf_gate.summary_table(MALFORMED_FRESH, MALFORMED, 1.5,
                                 "BENCH_x.json")
    assert "| `a` | 10.0 | 10.0 | 1.00x | ✅ ok |" in md
    assert "malformed" in md and "not gated" in md
    assert "inf" not in md and "FAIL" not in md


def test_gate_writes_step_summary(tmp_path, monkeypatch, capsys):
    """main() appends one markdown section per invocation to the file
    named by $GITHUB_STEP_SUMMARY; unset, it writes nothing anywhere."""
    fresh_p = tmp_path / "fresh.json"
    base_p = tmp_path / "BENCH_gossip.json"
    fresh_p.write_text(json.dumps(FRESH))
    base_p.write_text(json.dumps(BASE))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1  # a regressed
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1
    text = summary.read_text()
    assert text.count("### perf gate: `BENCH_gossip.json`") == 2  # appends
    assert "❌ FAIL" in text
    capsys.readouterr()

    monkeypatch.delenv("GITHUB_STEP_SUMMARY")
    summary.unlink()
    assert perf_gate.main([str(fresh_p), str(base_p)]) == 1
    assert not summary.exists()          # no-op without the env var
    capsys.readouterr()
