"""The async gossip execution engine: degenerate-gossip bit-parity pins
(simulator/spmd/fused, batch and streaming), CommState-keyed participation
randomness (the PR-4 contract extended to scheduling), no-(N, N) jaxpr
pinning at N=512, churn prefix-invariance, partial-participation
convergence (the acceptance criterion), grow/shrink helpers, and the
exec-axis validation surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_fit_parity, assert_gossip_degenerate

from repro.api import (Censor, Chain, ChurnSchedule, FitConfig, KRRConfig,
                       TopologySchedule, build_problem, fit, fit_stream,
                       sweep)
from repro.core import admm
from repro.core import gossip as G
from repro.core.graph import ring

KRR = KRRConfig(num_agents=8, samples_per_agent=12, num_features=16,
                lam=1e-3, rho=0.1, seed=0)
BATCH = FitConfig(krr=KRR, graph="ring", censor_v=0.3, censor_mu=0.97,
                  num_iters=40)
STREAM = FitConfig(algorithm="online_coke", krr=KRR, graph="ring",
                   censor_v=0.3, censor_mu=0.99, num_iters=60,
                   online_batch=6, online_lr=0.3)


def _run_stream(cfg, _prob):
    return fit_stream(cfg)


# ---------------------------------------------------------------------------
# The degenerate-gossip pin: participation=1.0 == sync, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["dkla", "coke"])
def test_degenerate_gossip_batch(algorithm):
    assert_gossip_degenerate(BATCH.replace(algorithm=algorithm),
                             ("simulator", "spmd", "fused"))


def test_degenerate_gossip_streaming():
    assert_gossip_degenerate(STREAM, ("simulator", "spmd"),
                             runner=_run_stream)


def test_gossip_masks_agree_across_backends():
    """At partial participation the simulator and the spmd ring must draw
    the SAME participation schedule (both derive it from the same
    CommState key), so comms/bits histories are bit-identical even though
    trajectories only float-match."""
    cfg = STREAM.replace(exec="gossip", participation=0.4)
    sim = fit_stream(cfg.replace(backend="simulator"))
    spmd = fit_stream(cfg.replace(backend="spmd"))
    for k in ("comms", "bits"):
        np.testing.assert_array_equal(np.asarray(sim.history[k]),
                                      np.asarray(spmd.history[k]),
                                      err_msg=f"gossip-mask:{k}")
    np.testing.assert_allclose(np.asarray(sim.theta),
                               np.asarray(spmd.theta), atol=1e-5)


# ---------------------------------------------------------------------------
# Participation randomness rides the CommState PRNG fold-in (the bugfix)
# ---------------------------------------------------------------------------

def test_participation_masks_fold_the_chain_key():
    """Masks are a pure function of (chain key, iteration, plan): same
    inputs reproduce bit-identically, a different censor parameter (hence
    a different chain key) or a different participation rate moves the
    whole schedule — no static seed anywhere."""
    plan = ChurnSchedule().plan(8, participation=0.5)
    ka = Chain((Censor(0.3, 0.97),)).chain_key()
    kb = Chain((Censor(0.5, 0.97),)).chain_key()

    def masks(key, p):
        return np.asarray([G.participation_mask(key, k, 8, p)
                           for k in range(1, 40)])

    assert np.array_equal(masks(ka, plan), masks(ka, plan))
    assert not np.array_equal(masks(ka, plan), masks(kb, plan))
    plan75 = ChurnSchedule().plan(8, participation=0.75)
    assert not np.array_equal(masks(ka, plan), masks(ka, plan75))


def test_sweep_cells_draw_independent_schedules():
    """Two identical sweep cells must be bit-identical; a cell with a
    different policy draws a different participation schedule (its chain
    key folds every numeric policy parameter)."""
    base = BATCH.replace(algorithm="coke", exec="gossip",
                         participation=0.5, censor_v=None, censor_mu=None)
    sw = sweep(base, [(0.3, 0.97), (0.3, 0.97), (0.5, 0.97)])
    comms = np.asarray(sw.history["comms"])
    np.testing.assert_array_equal(comms[0], comms[1],
                                  err_msg="identical cells must agree")
    assert not np.array_equal(comms[0], comms[2]), \
        "distinct cells must draw distinct participation schedules"


# ---------------------------------------------------------------------------
# No dense (N, N) on the gossip hot path (N=512 fits, N=2000+ scales)
# ---------------------------------------------------------------------------

def _count_nn_uses(jaxpr, n: int) -> int:
    """Number of equations CONSUMING an (n, n)-shaped value (recursively).
    The outvar counter alone would miss a step that merely reads the
    problem's adjacency invar without producing new (N, N) arrays."""
    hits = 0
    for eqn in jaxpr.eqns:
        for var in eqn.invars:
            shape = getattr(getattr(var, "aval", None), "shape", ())
            if tuple(shape[-2:]) == (n, n):
                hits += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            hits += _count_nn_uses(sub, n)
    return hits


def test_gossip_step_touches_no_dense_nn_at_512():
    from benchmarks.big_d_bench import count_dd_arrays

    n = 512
    cfg = FitConfig(
        krr=KRRConfig(num_agents=n, samples_per_agent=2, num_features=32,
                      lam=1e-3, rho=0.1, seed=0),
        graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97)
    problem = build_problem(cfg).problem
    policy = cfg.resolved_comm
    table = G.NeighborTable.from_adjacency(np.asarray(problem.adjacency))
    plan = ChurnSchedule().plan(n, participation=0.25)
    state0 = admm.init_state(problem, policy=policy)

    def gossip_step(problem, state):
        return G.gossip_coke_step(problem, policy, state, table, plan,
                                  primal="cg")

    jx = jax.make_jaxpr(gossip_step)(problem, state0).jaxpr
    assert count_dd_arrays(jx, n) == 0, \
        "gossip step materialized a dense (N, N) array"
    assert _count_nn_uses(jx, n) == 0, \
        "gossip step consumed the dense (N, N) adjacency"

    # the sync simulator step, by contrast, runs through the adjacency
    # matmul — the detector is live, not vacuously green
    def sync_step(problem, state):
        return admm.coke_step(problem, policy, state, None, primal="cg")

    assert _count_nn_uses(
        jax.make_jaxpr(sync_step)(problem, state0).jaxpr, n) > 0


# ---------------------------------------------------------------------------
# Churn: leave/rejoin mid-stream, survivors unperturbed up to the event
# ---------------------------------------------------------------------------

def test_churn_leave_rejoin_prefix_invariance():
    """An agent leaving at round 20 and rejoining at 50 must not disturb
    ANY agent's comms/bits/train-mse history before the leave event — the
    participation draw excludes liveness from the key fold, so the
    schedules coincide until the population actually changes."""
    churn = ChurnSchedule(leave=((20, 3),), join=((50, 3),))
    base = STREAM.replace(exec="gossip", participation=0.6, num_iters=80)
    with_churn = fit_stream(base.replace(churn=churn))
    without = fit_stream(base)
    for k in ("comms", "bits"):
        np.testing.assert_array_equal(
            np.asarray(with_churn.history[k])[:19],
            np.asarray(without.history[k])[:19],
            err_msg=f"churn-prefix:{k}")
    # trajectories coincide too — only to float tolerance, because the
    # churn program carries the alive-mask ops (different XLA fusion)
    np.testing.assert_allclose(
        np.asarray(with_churn.history["train_mse"])[:19],
        np.asarray(without.history["train_mse"])[:19],
        rtol=1e-5, err_msg="churn-prefix:train_mse")
    # the run still learns through the churn event
    inst = np.asarray(with_churn.history["instant_mse"])
    assert inst[-10:].mean() < inst[:10].mean()


def test_churn_parity_simulator_vs_spmd_batch():
    """Churn now runs on the spmd ring runtime: neighbor sums mask by the
    alive vector before the roll (two-term ring sums stay order-exact), so
    the same leave/rejoin schedule yields bit-identical comms/bits against
    the simulator and float-close thetas. primal="cg" keeps both backends
    on the matrix-free primal the traced alive mask requires."""
    churn = ChurnSchedule(leave=((5, 2),), join=((15, 2),))
    assert_fit_parity(
        BATCH.replace(algorithm="coke", exec="gossip", participation=0.6,
                      churn=churn, primal="cg", num_iters=25),
        ("simulator", "spmd"), exact=("comms", "bits"), theta_atol=1e-4)


def test_churn_parity_simulator_vs_spmd_streaming():
    """The streaming family's churn path gets the same cross-backend
    contract: one participation schedule, bit-identical bit accounting,
    float-close parameters through a leave/rejoin event."""
    churn = ChurnSchedule(leave=((20, 3),), join=((50, 3),))
    assert_fit_parity(
        STREAM.replace(exec="gossip", participation=0.6, churn=churn,
                       num_iters=80),
        ("simulator", "spmd"), runner=_run_stream,
        exact=("comms", "bits"), theta_atol=1e-4)


def test_straggler_slowdown_reduces_participation():
    """A 4x-slower agent participates ~4x less often, hence pays fewer
    bits; everyone else keeps the base rate."""
    churn = ChurnSchedule(slowdown=((0, 4.0),))
    res = fit_stream(STREAM.replace(exec="gossip", participation=0.8,
                                    churn=churn))
    bits = np.asarray(res.state.inner.comm.bits)
    assert bits[0] < 0.6 * bits[1:].mean()


def test_fixed_size_gossip_samples_exactly_k():
    """gossip_size=k draws exactly k participants per round; with
    censoring disabled every participant broadcasts, so the cumulative
    comms counter advances by exactly k each round."""
    res = fit_stream(STREAM.replace(exec="gossip", gossip_size=3,
                                    censor_v=0.0))
    comms = np.asarray(res.history["comms"])
    assert comms[0] == 3
    assert np.all(np.diff(comms) == 3)


def test_grow_take_agents_roundtrip():
    tree = {"theta": jnp.arange(24.0).reshape(8, 3),
            "step": jnp.zeros((), jnp.int32)}
    big = G.grow_agents(tree, 8, 12)
    assert big["theta"].shape == (12, 3)
    np.testing.assert_array_equal(np.asarray(big["theta"][8:]), 0.0)
    back = G.take_agents(big, 12, jnp.arange(8))
    np.testing.assert_array_equal(np.asarray(back["theta"]),
                                  np.asarray(tree["theta"]))


# ---------------------------------------------------------------------------
# Acceptance: partial participation still converges (N=200, p=0.25)
# ---------------------------------------------------------------------------

def test_quarter_participation_converges_n200():
    """gossip at participation=0.25 on N=200 reaches within 2x of the sync
    final train-MSE. Gossip gets 4x the rounds — equal EXPECTED per-agent
    work — which is the standard partial-participation accounting (each
    tick updates ~N/4 agents)."""
    cfg = FitConfig(
        krr=KRRConfig(num_agents=200, samples_per_agent=5, num_features=32,
                      lam=1e-3, rho=0.1, seed=0),
        graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
        primal="cg", num_iters=100)
    problem = build_problem(cfg).problem
    sync = fit(cfg, problem=problem)
    gsp = fit(cfg.replace(exec="gossip", participation=0.25,
                          num_iters=400), problem=problem)
    sync_mse = float(sync.history["train_mse"][-1])
    gsp_mse = float(gsp.history["train_mse"][-1])
    assert gsp_mse <= 2.0 * sync_mse, (gsp_mse, sync_mse)
    # sampling holds per-round traffic to ~N/4: across 4x the rounds the
    # total transmission count stays under 4x sync's censored total (and
    # far under the 400 * 200 full-broadcast count)
    assert float(gsp.history["comms"][-1]) < \
        4.0 * float(sync.history["comms"][-1])
    assert float(gsp.history["comms"][-1]) < 0.25 * 400 * 200


# ---------------------------------------------------------------------------
# Validation surface
# ---------------------------------------------------------------------------

def test_exec_axis_validation():
    with pytest.raises(ValueError, match="exec"):
        FitConfig(exec="async")
    # gossip knobs are rejected under sync — a silently ignored
    # participation rate would be a silently dropped experiment axis
    with pytest.raises(ValueError, match="participation"):
        FitConfig(participation=0.5)
    with pytest.raises(ValueError, match="gossip_size"):
        FitConfig(gossip_size=3)
    with pytest.raises(ValueError, match="churn"):
        FitConfig(churn=ChurnSchedule(leave=((5, 1),)))
    with pytest.raises(ValueError, match="participation"):
        FitConfig(exec="gossip", participation=0.0)
    with pytest.raises(ValueError, match="gossip_size"):
        FitConfig(exec="gossip", gossip_size=0)


def test_exec_support_validation():
    # CTA / the centralized oracle have no gossip semantics
    for algorithm in ("cta", "ridge_oracle"):
        with pytest.raises(ValueError, match="gossip"):
            fit(BATCH.replace(algorithm=algorithm, exec="gossip",
                              num_iters=2))
    # time-varying topology and gossip both rewrite the neighbor view
    adj = jnp.asarray(ring(8).adjacency, jnp.float32)
    topo = TopologySchedule(jnp.stack([adj, adj]))
    with pytest.raises(ValueError, match="topology"):
        fit(BATCH.replace(algorithm="coke", exec="gossip",
                          topology=topo, num_iters=2))
    # the fused kernel bakes static degrees; a traced alive mask can't
    with pytest.raises(ValueError, match="churn"):
        fit(BATCH.replace(algorithm="coke", exec="gossip", backend="fused",
                          churn=ChurnSchedule(leave=((5, 1),)),
                          num_iters=2))
    # a traced alive-mask makes degrees dynamic: no static Cholesky
    with pytest.raises(ValueError, match="Cholesky"):
        fit(BATCH.replace(algorithm="coke", exec="gossip",
                          primal="cholesky",
                          churn=ChurnSchedule(leave=((5, 1),)),
                          num_iters=2))


def test_churn_schedule_validation():
    with pytest.raises(ValueError, match="agent"):
        ChurnSchedule(leave=((5, 9),)).plan(8)
    with pytest.raises(ValueError, match="iteration"):
        ChurnSchedule(leave=((0, 1),)).plan(8)
    with pytest.raises(ValueError, match="conflict"):
        ChurnSchedule(leave=((5, 1),), join=((5, 1),)).plan(8)
    with pytest.raises(ValueError, match="factor"):
        ChurnSchedule(slowdown=((1, 0.5),)).plan(8)
    with pytest.raises(ValueError, match="size"):
        ChurnSchedule().plan(8, size=9)


def test_exec_recorded_in_model_meta():
    res = fit(BATCH.replace(algorithm="coke", exec="gossip",
                            participation=0.5, num_iters=4))
    assert res.to_model().meta["exec"] == "gossip"
