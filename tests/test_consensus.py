"""Consensus DP strategies over the agent axis: DKLA/COKE reach the
allreduce solution on a convex problem; censoring saves transmissions;
ring neighbor exchange semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import consensus as cns
from repro.optim.optimizers import OptConfig

N_AGENTS = 8


def _quadratic_problem(seed=0):
    """Each agent i has loss ||A_i x - b_i||^2; global optimum solves the
    stacked least squares."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(N_AGENTS, 6, 4)).astype(np.float32)
    b = rng.normal(size=(N_AGENTS, 6)).astype(np.float32)
    A_all = A.reshape(-1, 4)
    b_all = b.reshape(-1)
    x_star = np.linalg.lstsq(A_all, b_all, rcond=None)[0]
    return jnp.asarray(A), jnp.asarray(b), x_star


def _grads(A, b, params):
    def loss(x, Ai, bi):
        r = Ai @ x - bi
        return jnp.mean(r * r)
    return jax.vmap(jax.grad(loss))(params["x"], A, b)


def _run(strategy, steps=1500, rho=0.05, v=0.3, mu=0.995, lr=0.05):
    A, b, x_star = _quadratic_problem()
    ccfg = cns.ConsensusConfig(strategy=strategy, rho=rho, censor_v=v,
                               censor_mu=mu)
    opt_cfg = OptConfig(kind="sgd", lr=lr)
    params = {"x": jnp.zeros((N_AGENTS, 4))}
    state = cns.init_consensus_state(ccfg, opt_cfg, params)

    @jax.jit
    def step(params, state):
        grads = {"x": _grads(A, b, params)}
        return cns.consensus_update(ccfg, opt_cfg, params, grads, state)

    for _ in range(steps):
        params, state, metrics = step(params, state)
    return params, state, x_star


def test_dkla_dp_reaches_global_optimum():
    params, state, x_star = _run("dkla")
    err = np.abs(np.asarray(params["x"]) - x_star[None]).max()
    assert err < 5e-2, err
    assert float(cns.consensus_gap(params)) < 5e-2


def test_coke_dp_reaches_global_optimum_with_fewer_comms():
    params_c, state_c, x_star = _run("coke")
    err = np.abs(np.asarray(params_c["x"]) - x_star[None]).max()
    assert err < 8e-2, err
    _, state_d, _ = _run("dkla")
    assert int(state_c["comms"]) < int(state_d["comms"])
    assert int(state_c["comms"]) > 0


def test_cta_dp_converges_to_consensus():
    """Diffusion with constant stepsize has an O(lr * heterogeneity)
    steady-state consensus error — assert the mean iterate approaches the
    global optimum and the gap is bounded, not exact."""
    params, state, x_star = _run("cta", steps=2000, lr=0.05)
    assert float(cns.consensus_gap(params)) < 0.5
    err = np.abs(np.asarray(params["x"]).mean(0) - x_star).max()
    assert err < 1e-1, err


def test_ring_neighbors_roll_semantics():
    tree = {"w": jnp.arange(5 * 3, dtype=jnp.float32).reshape(5, 3)}
    left, right = cns._ring_neighbors(tree)
    np.testing.assert_array_equal(np.asarray(left["w"][0]),
                                  np.asarray(tree["w"][-1]))
    np.testing.assert_array_equal(np.asarray(right["w"][-1]),
                                  np.asarray(tree["w"][0]))


def test_local_update_touches_no_consensus_state():
    A, b, _ = _quadratic_problem()
    ccfg = cns.ConsensusConfig(strategy="coke_et", rho=0.05)
    opt_cfg = OptConfig(kind="sgd", lr=0.1)
    params = {"x": jnp.zeros((N_AGENTS, 4))}
    state = cns.init_consensus_state(ccfg, opt_cfg, params)
    grads = {"x": _grads(A, b, params)}
    params2, state2 = cns.local_update(opt_cfg, params, grads, state)
    np.testing.assert_array_equal(np.asarray(state2["theta_hat"]["x"]),
                                  np.asarray(state["theta_hat"]["x"]))
    assert int(state2["comms"]) == int(state["comms"])
    assert not np.allclose(np.asarray(params2["x"]),
                           np.asarray(params["x"]))


def test_agent_norms_per_agent():
    tree = {"a": jnp.ones((3, 4)), "b": 2 * jnp.ones((3, 2))}
    norms = cns._agent_norms(tree)
    # per agent: 4 * 1^2 + 2 * 2^2 = 12
    np.testing.assert_allclose(np.asarray(norms),
                               np.sqrt(12.0) * np.ones(3), rtol=1e-6)


def test_circulant_topology_converges_and_densifies():
    """Circulant offsets generalize the ring; denser graphs reach consensus
    faster (Thm 2: larger sigma_min(S_-))."""
    A, b, x_star = _quadratic_problem()

    def gap_after(offsets, steps=400):
        ccfg = cns.ConsensusConfig(strategy="dkla", rho=0.05,
                                   offsets=offsets)
        opt_cfg = OptConfig(kind="sgd", lr=0.05)
        params = {"x": jnp.zeros((N_AGENTS, 4))}
        state = cns.init_consensus_state(ccfg, opt_cfg, params)

        @jax.jit
        def step(params, state):
            grads = {"x": _grads(A, b, params)}
            return cns.consensus_update(ccfg, opt_cfg, params, grads, state)

        for _ in range(steps):
            params, state, _ = step(params, state)
        err = np.abs(np.asarray(params["x"]) - x_star[None]).max()
        return float(cns.consensus_gap(params)), err

    gap_ring, err_ring = gap_after((1,))
    gap_dense, err_dense = gap_after((1, 2))
    assert err_ring < 0.15 and err_dense < 0.15
    assert gap_dense <= gap_ring + 1e-6


def test_fused_kernel_path_matches_standard():
    """ConsensusConfig(use_fused_kernel=True) routes the augmented gradient
    through the Pallas coke_update kernel — iterates must match the jnp
    path to float32 roundoff."""
    A, b, _ = _quadratic_problem()
    opt_cfg = OptConfig(kind="sgd", lr=0.05)

    def run(fused, steps=30):
        ccfg = cns.ConsensusConfig(strategy="coke", rho=0.05,
                                   censor_v=0.05, censor_mu=0.99,
                                   use_fused_kernel=fused)
        params = {"x": jnp.zeros((N_AGENTS, 4))}
        state = cns.init_consensus_state(ccfg, opt_cfg, params)
        for _ in range(steps):
            grads = {"x": _grads(A, b, params)}
            params, state, _ = cns.consensus_update(ccfg, opt_cfg, params,
                                                    grads, state)
        return params, state

    p0, s0 = run(False)
    p1, s1 = run(True)
    np.testing.assert_allclose(np.asarray(p0["x"]), np.asarray(p1["x"]),
                               atol=1e-6)
    assert int(s0["comms"]) == int(s1["comms"])
