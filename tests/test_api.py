"""The unified `repro.api` surface: registry round-trip, `fit()` parity
with the legacy drivers (bit-identical trajectories), backend parity
(simulator vs SPMD vs fused Pallas kernel), and the sweep-compilation
contract (traced censor thresholds -> one compiled loop)."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_fit_parity

from repro.api import (FitConfig, KRRConfig, build_problem, fit, get_solver,
                       list_solvers)
from repro.api.fit import _simulator_chunk
from repro.api.registry import Solver
from repro.core import admm, cta
from repro.core.censor import CensorSchedule

KRR = KRRConfig(num_agents=6, samples_per_agent=50, num_features=16,
                lam=1e-2, rho=0.5, seed=0)
BASE = FitConfig(krr=KRR, algorithm="coke", censor_v=0.5, censor_mu=0.97,
                 num_iters=60)


@pytest.fixture(scope="module")
def built():
    return build_problem(BASE)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_roundtrip():
    names = list_solvers()
    assert {"dkla", "coke", "cta", "online_dkla", "online_coke",
            "qc_odkla", "ridge_oracle"} <= set(names)
    for name in names:
        s = get_solver(name)
        assert isinstance(s, Solver)
        assert s.name == name
        assert set(s.backends) <= {"simulator", "spmd", "fused"}


def test_registry_unknown_name_lists_alternatives():
    with pytest.raises(KeyError, match="unknown solver.*coke"):
        get_solver("no_such_algorithm")


def test_unsupported_backend_rejected(built):
    with pytest.raises(ValueError, match="backends"):
        fit(BASE.replace(algorithm="online_coke", backend="spmd"),
            problem=built.problem)
    with pytest.raises(ValueError, match="unknown backend"):
        BASE.replace(backend="gpu_cluster")
    with pytest.raises(ValueError, match="chunk_size"):
        BASE.replace(chunk_size=0)


# ---------------------------------------------------------------------------
# fit() parity vs the legacy entry points
# ---------------------------------------------------------------------------

def _legacy_admm(problem, schedule, iters):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return admm.run(problem, schedule, iters)


def test_fit_dkla_bit_identical_to_legacy(built):
    legacy = _legacy_admm(built.problem, admm.dkla_schedule(), 60)
    new = fit(BASE.replace(algorithm="dkla"), problem=built.problem)
    np.testing.assert_array_equal(np.asarray(legacy.train_mse),
                                  np.asarray(new.train_mse))
    np.testing.assert_array_equal(np.asarray(legacy.comms),
                                  np.asarray(new.comms))
    np.testing.assert_array_equal(np.asarray(legacy.consensus_gap),
                                  np.asarray(new.consensus_gap))
    np.testing.assert_array_equal(np.asarray(legacy.state.theta),
                                  np.asarray(new.theta))


def test_fit_coke_bit_identical_to_legacy(built):
    legacy = _legacy_admm(built.problem, CensorSchedule(0.5, 0.97), 60)
    new = fit(BASE, problem=built.problem)
    np.testing.assert_array_equal(np.asarray(legacy.train_mse),
                                  np.asarray(new.train_mse))
    np.testing.assert_array_equal(np.asarray(legacy.comms),
                                  np.asarray(new.comms))
    assert int(new.comms[-1]) < 60 * KRR.num_agents  # censoring active


def test_fit_cta_matches_legacy(built):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = cta.run(built.problem, built.graph, lr=0.9, num_iters=60)
    new = fit(BASE.replace(algorithm="cta", cta_lr=0.9),
              problem=built.problem)
    np.testing.assert_array_equal(np.asarray(legacy.train_mse),
                                  np.asarray(new.train_mse))
    np.testing.assert_array_equal(np.asarray(legacy.comms),
                                  np.asarray(new.comms))


def test_legacy_entry_points_warn(built):
    with pytest.warns(DeprecationWarning, match="repro.api.fit"):
        admm.run(built.problem, admm.dkla_schedule(), 2)
    with pytest.warns(DeprecationWarning, match="repro.api.fit"):
        cta.run(built.problem, built.graph, lr=0.9, num_iters=2)


# ---------------------------------------------------------------------------
# Compilation contract: censor sweeps share one compiled loop
# ---------------------------------------------------------------------------

def test_censor_sweep_reuses_one_compiled_loop(built):
    fit(BASE, problem=built.problem)  # warm the cache
    n0 = _simulator_chunk._cache_size()
    savings = []
    for v, mu in ((0.05, 0.99), (0.2, 0.98), (0.8, 0.96), (1.5, 0.95)):
        r = fit(BASE.replace(censor_v=v, censor_mu=mu),
                problem=built.problem)
        savings.append(int(r.comms[-1]))
    assert _simulator_chunk._cache_size() == n0, \
        "sweeping (v, mu) must not retrace the fit loop"
    # the sweep really did run different schedules
    assert len(set(savings)) > 1


# ---------------------------------------------------------------------------
# Backend parity on 4 agents (ring: what the SPMD runtime implements)
# ---------------------------------------------------------------------------

RING = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=40, num_features=32,
                  lam=1e-2, rho=0.1, seed=0),
    graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
    num_iters=80, primal="gradient", inner_steps=1, inner_lr=0.05)


@pytest.fixture(scope="module")
def ring_built():
    return build_problem(RING)


@pytest.mark.parametrize("algorithm", ["dkla", "coke"])
def test_backend_parity(ring_built, backend_pair, algorithm):
    """Every backend pair agrees on every iteration's send count exactly
    and on the trajectories/final thetas to float tolerance — the
    conformance contract new backends must pass."""
    assert_fit_parity(RING.replace(algorithm=algorithm), backend_pair,
                      problem=ring_built.problem, exact=("comms",),
                      theta_atol=1e-5, close={"train_mse": dict(atol=1e-6)})


def test_spmd_rejects_noncirculant_graph(built):
    # BASE's problem lives on an Erdos-Renyi graph
    with pytest.raises(ValueError, match="circulant"):
        fit(BASE.replace(backend="spmd"), problem=built.problem)


def test_cross_backend_comm_parity_bit_for_bit(ring_built):
    """Satellite: simulator, spmd and fused must agree bit-for-bit on send
    decisions and quantized payload accounting for a fixed policy key —
    all three run the SAME core.comm decision code on the same message."""
    from repro.api import Censor, Chain, Drop, Quantize

    cfg = RING.replace(
        censor_v=None, censor_mu=None,
        comm=Chain([Censor(0.3, 0.97), Quantize(bits=5, seed=7),
                    Drop(p=0.15, seed=11)]))
    # cumulative send decisions identical at every iteration => the
    # per-iteration decision sequence is identical; every transmission
    # accounted at the same bit width; the quantized broadcasts drive
    # near-identical trajectories
    runs = assert_fit_parity(cfg, ("simulator", "spmd", "fused"),
                             problem=ring_built.problem,
                             exact=("comms", "bits"), theta_atol=1e-5)
    # the policy actually engaged: some sends censored, some payloads lost
    sim = runs["simulator"]
    assert 0 < int(sim.comms[-1]) < RING.resolved_iters * 4


# ---------------------------------------------------------------------------
# Driver plumbing: chunked callbacks, oracle distance, remaining solvers
# ---------------------------------------------------------------------------

def test_chunked_fit_trajectory_identical_and_callbacks_fire(built):
    full = fit(BASE, problem=built.problem)
    seen = []
    chunked = fit(BASE.replace(chunk_size=25), problem=built.problem,
                  progress_cb=lambda k, m: seen.append((k, m)))
    assert [k for k, _ in seen] == [25, 50, 60]
    assert all("train_mse" in m for _, m in seen)
    np.testing.assert_array_equal(np.asarray(full.train_mse),
                                  np.asarray(chunked.train_mse))
    np.testing.assert_array_equal(np.asarray(full.comms),
                                  np.asarray(chunked.comms))


def test_chunk_boundary_parity_bit_identical(built):
    """chunk_size None / divisor / non-divisor must yield bit-identical
    trajectories and final thetas, and progress_cb must fire once per chunk
    with the running iteration count."""
    runs = {}
    fired = {}
    # 60 iters: None = one scan; 20 divides; 25 leaves a short tail chunk
    for cs, expected in ((None, [60]), (20, [20, 40, 60]), (25, [25, 50, 60])):
        seen = []
        runs[cs] = fit(BASE.replace(chunk_size=cs), problem=built.problem,
                       progress_cb=lambda k, m: seen.append(k))
        fired[cs] = seen
        assert seen == expected, (cs, seen)
    ref = runs[None]
    for cs in (20, 25):
        r = runs[cs]
        for key in ref.history:
            np.testing.assert_array_equal(np.asarray(ref.history[key]),
                                          np.asarray(r.history[key]),
                                          err_msg=f"chunk_size={cs}:{key}")
        np.testing.assert_array_equal(np.asarray(ref.theta),
                                      np.asarray(r.theta))


def test_oracle_distance_recorded_and_shrinks(built):
    r = fit(BASE.replace(algorithm="dkla", num_iters=600,
                         record_oracle_distance=True),
            problem=built.problem)
    d = r.history["dist_to_oracle"]
    assert d.shape == (600,)
    assert float(d[-1]) < 0.2 * float(d[0])


def test_ridge_oracle_solver_beats_iterates(built):
    oracle = fit(BASE.replace(algorithm="ridge_oracle", num_iters=1),
                 problem=built.problem)
    assert int(oracle.comms[-1]) == 0
    assert float(oracle.consensus_gap[-1]) < 1e-6  # identical on all agents
    dkla = fit(BASE.replace(algorithm="dkla", num_iters=30),
               problem=built.problem)
    # the oracle attains at-most the truncated iterate's training MSE
    assert float(oracle.train_mse[-1]) <= float(dkla.train_mse[-1]) + 1e-9


def test_online_coke_via_fit_learns_and_censors(built):
    r = fit(BASE.replace(algorithm="online_coke", num_iters=300,
                         online_lr=0.3, censor_v=0.2, censor_mu=0.995),
            problem=built.problem)
    inst = r.history["instant_mse"]
    assert float(jnp.mean(inst[-20:])) < float(jnp.mean(inst[1:21]))
    assert int(r.comms[-1]) < 300 * KRR.num_agents


def test_fit_zero_iters_yields_empty_history(built):
    r = fit(BASE.replace(num_iters=0), problem=built.problem)
    assert r.train_mse.shape == (0,)
    assert r.theta.shape == (KRR.num_agents, KRR.num_features)
    seen = []
    r = fit(BASE.replace(num_iters=0, chunk_size=8), problem=built.problem,
            progress_cb=lambda k, m: seen.append(k))
    assert r.train_mse.shape == (0,) and seen == []


def test_fit_builds_problem_from_config_alone():
    r = fit(FitConfig(krr=KRRConfig(num_agents=4, samples_per_agent=30,
                                    num_features=8),
                      algorithm="dkla", num_iters=5))
    assert r.train_mse.shape == (5,)
    assert r.theta.shape == (4, 8)
