"""Shared fixtures + the cross-backend conformance harness.

NOTE: no XLA device-count flags here — smoke tests and benches must see
the host's real (single) device; only the dry-run sets the 512-device
flag, inside its own process.

The conformance harness is the one way parity is pinned across backends
(and across config variants that must agree): `assert_fit_parity` runs a
config on several backends and checks the contract every backend pair in
this repo satisfies — bit-identical send decisions and bit accounting
(`exact` history keys), float-close trajectories and final thetas.
`assert_results_match` is the underlying two-run comparator, reused for
same-backend contracts (identity chains, primal-mode parity). Every new
backend/solver must pass through these rather than hand-rolled asserts.
"""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Cross-backend conformance harness
# ---------------------------------------------------------------------------

#: every backend pair the batch solvers must agree across
BACKEND_PAIRS = (("simulator", "spmd"), ("simulator", "fused"),
                 ("spmd", "fused"))


@pytest.fixture(params=BACKEND_PAIRS, ids=lambda p: f"{p[0]}-vs-{p[1]}")
def backend_pair(request):
    """Parametrizes a test over every backend pair."""
    return request.param


def assert_results_match(ref, other, *, exact=(), theta_atol=None,
                         close=None, err=""):
    """Pin parity between two FitResults.

    exact      — history keys that must match bit-for-bit; the string "*"
                 means every key of `ref.history` AND the final theta
                 (the identity-chain / bit-parity contract).
    theta_atol — absolute tolerance for the final theta stack (None =
                 skip, unless exact="*").
    close      — {history_key: assert_allclose kwargs} for float-close
                 trajectory keys; keys missing from either history are an
                 error (a silently skipped key is a silently dropped pin).
    """
    if exact == "*":
        for k in ref.history:
            np.testing.assert_array_equal(
                np.asarray(ref.history[k]), np.asarray(other.history[k]),
                err_msg=f"{err}:{k}")
        np.testing.assert_array_equal(np.asarray(ref.theta),
                                      np.asarray(other.theta),
                                      err_msg=f"{err}:theta")
        return
    for k in exact:
        np.testing.assert_array_equal(
            np.asarray(ref.history[k]), np.asarray(other.history[k]),
            err_msg=f"{err}:{k}")
    for k, kw in (close or {}).items():
        np.testing.assert_allclose(
            np.asarray(ref.history[k]), np.asarray(other.history[k]),
            err_msg=f"{err}:{k}", **kw)
    if theta_atol is not None:
        np.testing.assert_allclose(np.asarray(ref.theta),
                                   np.asarray(other.theta),
                                   atol=theta_atol,
                                   err_msg=f"{err}:theta")


def assert_fit_parity(config, backends, *, problem=None, runner=None,
                      exec_mode="sync", exact=("comms",), theta_atol=1e-5,
                      close=None):
    """Run `config` on every backend in `backends` and pin cross-backend
    parity against the first (the reference).

    runner    — None = `repro.api.fit`; pass a callable (config, problem)
                -> FitResult to conform other drivers (e.g. `fit_stream`,
                with the StreamProblem as `problem`).
    exec_mode — "sync" runs the config as-is. "degenerate-gossip" runs
                BOTH executions per backend and pins the degenerate
                contract: `exec="gossip"` at participation=1.0 with zero
                staleness (no churn, no stragglers) must reproduce
                `exec="sync"` BIT-FOR-BIT — every masked update collapses
                to the synchronous step, the all-true participation mask
                is drawn but selects everything, and non-participation
                bit savings are vacuous. Use deg-2 (ring) graphs there:
                the gather-based neighbor sum is bitwise equal to the
                dense adjacency matmul (two-term sums are order-exact),
                which is what makes the pin exact rather than close.
                Cross-backend parity (exact/theta_atol/close) is then
                pinned on the gossip runs.
    Returns {backend: FitResult} for "sync",
    {backend: (sync_result, gossip_result)} for "degenerate-gossip".
    """
    from repro.api import fit

    if runner is None:
        def runner(cfg, prob):
            return fit(cfg, problem=prob)
    results, pairs = {}, {}
    for b in backends:
        cfg = config.replace(backend=b)
        if exec_mode == "sync":
            results[b] = runner(cfg, problem)
        elif exec_mode == "degenerate-gossip":
            sync = runner(cfg.replace(exec="sync"), problem)
            gsp = runner(cfg.replace(exec="gossip", participation=1.0),
                         problem)
            assert_results_match(sync, gsp, exact="*",
                                 err=f"gossip-degenerate:{b}")
            results[b] = gsp
            pairs[b] = (sync, gsp)
        else:
            raise ValueError(f"unknown exec_mode {exec_mode!r}")
    ref = results[backends[0]]
    for b in backends[1:]:
        assert_results_match(ref, results[b], exact=exact,
                             theta_atol=theta_atol, close=close,
                             err=f"{backends[0]}-vs-{b}")
    return pairs if exec_mode == "degenerate-gossip" else results


def assert_gossip_degenerate(config, backends, *, problem=None,
                             runner=None):
    """The degenerate-gossip pin, routed through `assert_fit_parity`
    (exec_mode="degenerate-gossip") so sync and gossip conformance share
    one code path. Cross-backend keys beyond the per-backend bit-exact
    contract are left to callers (exact=(), theta_atol=None here keeps
    this a pure degeneracy pin, as it always was).
    Returns {backend: (sync_result, gossip_result)}.
    """
    return assert_fit_parity(config, backends, problem=problem,
                             runner=runner, exec_mode="degenerate-gossip",
                             exact=(), theta_atol=None)
