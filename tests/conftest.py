"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the host's real (single) device; only the dry-run sets the
512-device flag, inside its own process."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
