"""The streaming subsystem: StreamProblem construction, fit_stream through
the façade (registry, history, to_model, partial_fit), the QC-ODKLA
identity-chain contract (simulator AND spmd, pinned via the conformance
harness), cross-backend streaming parity, and the `core.online` edge cases
(schedule=None vs identity chain, comms monotonicity, legacy state
alignment, stationary-stream regret)."""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_fit_parity, assert_results_match
from hypothesis_compat import given, settings, st

from repro.api import (Censor, Chain, Drop, FitConfig, KRRConfig, Quantize,
                       StreamProblem, build_stream, fit, fit_stream,
                       get_solver, stream_from_arrays)
from repro.core import comm as comm_mod
from repro.core import online
from repro.core.graph import ring
from repro.data.synthetic import STREAM_KINDS, stream_synthetic

KRR = KRRConfig(num_agents=6, samples_per_agent=50, num_features=16,
                lam=1e-2, rho=0.1, seed=0)
BASE = FitConfig(krr=KRR, algorithm="online_coke", graph="ring",
                 censor_v=0.3, censor_mu=0.99, num_iters=80,
                 online_batch=8, online_lr=0.3)


@pytest.fixture(scope="module")
def built():
    return build_stream(BASE)


def _run(cfg, stream):
    return fit_stream(cfg, stream=stream)


# ---------------------------------------------------------------------------
# Generators and StreamProblem construction
# ---------------------------------------------------------------------------

def test_stream_generators_shapes_and_kinds():
    for kind in STREAM_KINDS:
        ds = stream_synthetic(kind=kind, num_rounds=12, num_agents=3,
                              batch=4, seed=1)
        assert ds.x.shape == (12, 3, 4, 5) and ds.y.shape == (12, 3, 4)
        assert ds.kind == kind
        assert 0.0 <= ds.x.min() and ds.x.max() <= 1.0
    with pytest.raises(ValueError, match="stream kind"):
        stream_synthetic(kind="cyclic")


def test_drift_moves_the_target_and_shift_moves_the_inputs():
    stat = stream_synthetic("stationary", num_rounds=40, num_agents=3,
                            batch=8, seed=2)
    drift = stream_synthetic("drift", num_rounds=40, num_agents=3,
                             batch=8, seed=2)
    shift = stream_synthetic("shift", num_rounds=40, num_agents=3,
                             batch=8, seed=2)
    # concept drift: identical raw inputs, different late-round labels
    np.testing.assert_allclose(stat.x, drift.x, atol=1e-6)
    assert np.abs(stat.y[-1] - drift.y[-1]).max() > 1e-3
    # covariate shift: some input coordinate's mean moves between early
    # and late rounds, far beyond the stationary sampling noise
    d_stat = np.abs(stat.x[:5].mean((0, 1, 2))
                    - stat.x[-5:].mean((0, 1, 2))).max()
    d_shift = np.abs(shift.x[:5].mean((0, 1, 2))
                     - shift.x[-5:].mean((0, 1, 2))).max()
    assert d_shift > 3 * max(d_stat, 1e-5)


def test_build_stream_and_from_arrays_validate(built):
    s = built.stream
    assert isinstance(s, StreamProblem)
    assert s.feats.shape == (80, 6, 8, 16) and s.labels.shape == (80, 6, 8)
    assert s.num_rounds == 80 and s.num_agents == 6 and s.batch == 8
    with pytest.raises(ValueError, match=r"\(R, N, b, d\)"):
        stream_from_arrays(built.rff_params, np.zeros((4, 3, 2)),
                           np.zeros((4, 3, 2)), ring(3), lam=0.1, rho=0.1)
    with pytest.raises(ValueError, match="stream kind"):
        BASE.replace(stream="cyclic")
    with pytest.raises(ValueError, match="qc_eta"):
        BASE.replace(qc_eta=-1.0)


# ---------------------------------------------------------------------------
# fit_stream through the façade
# ---------------------------------------------------------------------------

def test_streaming_solvers_registered_and_marked():
    for name in ("online_dkla", "online_coke", "qc_odkla"):
        s = get_solver(name)
        assert s.streaming
        assert s.stream_backends == ("simulator", "spmd")
        assert s.backends == ("simulator",)  # batch fit() stays simulator


def test_fit_stream_learns_censors_and_deploys(built):
    r = fit_stream(BASE, stream=built.stream)
    inst = r.history["instant_mse"]
    assert inst.shape == (80,)
    # regret: the late-stream instantaneous MSE beats the early one
    assert float(jnp.mean(inst[-10:])) < float(jnp.mean(inst[1:11]))
    # censoring engaged
    assert 0 < int(r.comms[-1]) < 80 * KRR.num_agents
    # bits accounted for every transmission at full precision
    np.testing.assert_array_equal(
        np.asarray(r.bits),
        np.asarray(r.comms) * KRR.num_features * 32)
    # the streaming fit deploys exactly like a batch fit
    model = r.to_model(built.rff_params)
    preds = model.predict(np.asarray(built.dataset.x[-1, 0]))
    assert preds.shape == (8,)
    assert float(np.mean((np.asarray(preds)
                          - built.dataset.y[-1, 0]) ** 2)) < 0.1


def test_fit_stream_builds_stream_from_config_alone():
    r = fit_stream(BASE.replace(num_iters=12))
    assert r.history["instant_mse"].shape == (12,)
    assert r.rff_params is not None
    assert r.to_model().num_features == KRR.num_features


def test_fit_stream_rejects_misuse(built):
    with pytest.raises(ValueError, match="batch algorithm"):
        fit_stream(BASE.replace(algorithm="coke"), stream=built.stream)
    with pytest.raises(ValueError, match="backends"):
        fit_stream(BASE.replace(backend="fused"), stream=built.stream)
    with pytest.raises(ValueError, match="primal"):
        fit_stream(BASE.replace(primal="cg"), stream=built.stream)
    with pytest.raises(ValueError, match="fit_stream"):
        fit(BASE, problem=built.stream)
    from repro.core.graph import TopologySchedule
    with pytest.raises(ValueError, match="static"):
        fit_stream(BASE.replace(
            topology=TopologySchedule.circulant_cycle(6, [(1,)])),
            stream=built.stream)


def test_online_dkla_strips_censor_but_keeps_compression(built):
    r = fit_stream(BASE.replace(
        algorithm="online_dkla", censor_v=None, censor_mu=None,
        comm=Chain([Censor(5.0, 0.999), Quantize(bits=8)])),
        stream=built.stream)
    assert int(r.comms[-1]) == 80 * KRR.num_agents  # always transmits
    assert int(r.bits[-1]) == 80 * KRR.num_agents * (
        KRR.num_features * 8 + 32)


def test_chunked_fit_stream_trajectory_identical(built):
    full = fit_stream(BASE, stream=built.stream)
    seen = []
    chunked = fit_stream(BASE.replace(chunk_size=32), stream=built.stream,
                         progress_cb=lambda k, m: seen.append(k))
    assert seen == [32, 64, 80]
    assert_results_match(full, chunked, exact="*", err="chunked")


# ---------------------------------------------------------------------------
# Acceptance: the QC-ODKLA identity-chain contract, simulator AND spmd
# ---------------------------------------------------------------------------

IDENT = Chain([Censor(0.3, 0.99), Quantize(bits=float("inf")),
               Drop(p=0.0)])


@pytest.mark.parametrize("backend", ["simulator", "spmd"])
def test_qc_odkla_identity_chain_bit_identical_to_online_coke(built,
                                                              backend):
    """Acceptance: fit_stream with qc_odkla + Chain([Censor(v, mu),
    Quantize(inf), Drop(0)]) is bit-identical to online_coke with
    Censor(v, mu) — the identity-chain contract extended to the streaming
    path, on both wired backends."""
    coke = fit_stream(BASE.replace(backend=backend), stream=built.stream)
    qc = fit_stream(BASE.replace(
        backend=backend, algorithm="qc_odkla",
        censor_v=None, censor_mu=None, comm=IDENT), stream=built.stream)
    assert_results_match(coke, qc, exact="*", err=backend)
    # the contract is non-vacuous: censoring actually engaged
    assert 0 < int(coke.comms[-1]) < 80 * KRR.num_agents


def test_streaming_simulator_vs_spmd_parity(built):
    """Cross-backend conformance for the streaming family: identical send
    decisions and bit accounting at every round, float-close regret
    trajectories and thetas — and key-identical histories, so any pair is
    comparable with exact="*"."""
    for algorithm in ("online_dkla", "online_coke", "qc_odkla"):
        runs = assert_fit_parity(
            BASE.replace(algorithm=algorithm),
            ("simulator", "spmd"), problem=built.stream, runner=_run,
            exact=("comms", "bits"), theta_atol=1e-5,
            close={"instant_mse": dict(atol=1e-6),
                   "consensus_gap": dict(atol=1e-6)})
        assert (set(runs["simulator"].history)
                == set(runs["spmd"].history)), algorithm


def test_qc_odkla_explicit_eta_differs_but_converges(built):
    """With an explicit proximal coefficient the linearized-ADMM step is a
    genuinely different update (per-agent stepsize 1/(eta + 2 rho deg)) —
    trajectories diverge from online_coke but still learn."""
    qc = fit_stream(BASE.replace(algorithm="qc_odkla", qc_eta=2.0),
                    stream=built.stream)
    coke = fit_stream(BASE, stream=built.stream)
    assert not np.array_equal(np.asarray(qc.theta), np.asarray(coke.theta))
    inst = qc.history["instant_mse"]
    assert float(jnp.mean(inst[-10:])) < float(jnp.mean(inst[1:11]))


# ---------------------------------------------------------------------------
# Satellite: property test — the identity contract over random streams
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=15)
@given(st.floats(0.0, 2.0), st.floats(0.8, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_qc_odkla_identity_holds_for_any_stream_and_censor(v, mu, seed):
    """For ANY stream and ANY censor (v, mu): qc_odkla with bits=inf and
    drop p=0 matches online_coke exactly, round for round."""
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(10, 4, 3, 6)), jnp.float32)
    labels = jnp.asarray(rng.normal(size=(10, 4, 3)), jnp.float32)
    stream = StreamProblem(feats=feats, labels=labels,
                           adjacency=jnp.asarray(ring(4).adjacency,
                                                 jnp.float32),
                           lam=1e-2, rho=0.1)
    base = FitConfig(krr=KRRConfig(num_agents=4, num_features=6),
                     graph="ring", num_iters=10, online_batch=3,
                     censor_v=None, censor_mu=None)
    coke = fit_stream(base.replace(algorithm="online_coke",
                                   comm=Chain([Censor(v, mu)])),
                      stream=stream)
    qc = fit_stream(base.replace(
        algorithm="qc_odkla",
        comm=Chain([Censor(v, mu), Quantize(bits=float("inf")),
                    Drop(p=0.0)])), stream=stream)
    assert_results_match(coke, qc, exact="*", err=f"v={v},mu={mu}")


# ---------------------------------------------------------------------------
# partial_fit: the deploy -> refine loop
# ---------------------------------------------------------------------------

def test_partial_fit_warm_starts_from_deployed_model(built):
    batch_cfg = FitConfig(krr=KRR, algorithm="coke", graph="ring",
                          censor_v=0.3, censor_mu=0.99, num_iters=150)
    model = fit(batch_cfg).to_model()
    refined, res = model.partial_fit(built.stream,
                                     BASE.replace(num_iters=40))
    # warm start: the very first regret sample scores with the trained
    # model, far below a cold start's
    cold = fit_stream(BASE.replace(num_iters=40), stream=built.stream)
    assert float(res.history["instant_mse"][0]) < 0.5 * float(
        cold.history["instant_mse"][0])
    assert refined.meta["warm_started"] is True
    assert refined.meta["refined_from"]["algorithm"] == "coke"
    assert refined.num_features == model.num_features
    # raw-array spelling featurizes with the model's own map
    refined2, res2 = model.partial_fit(
        np.asarray(built.dataset.x[:10]),
        labels=np.asarray(built.dataset.y[:10]),
        config=BASE.replace(num_iters=10))
    assert refined2.meta["warm_started"] is True
    # an explicit config's krr.lam/rho reach the built stream — a config
    # with a very different ridge term must change the dynamics
    import dataclasses
    heavy = BASE.replace(num_iters=10,
                         krr=dataclasses.replace(KRR, lam=10.0))
    _, res3 = model.partial_fit(np.asarray(built.dataset.x[:10]),
                                labels=np.asarray(built.dataset.y[:10]),
                                config=heavy)
    assert not np.array_equal(np.asarray(res2.history["instant_mse"]),
                              np.asarray(res3.history["instant_mse"]))
    with pytest.raises(ValueError, match="labels"):
        model.partial_fit(np.asarray(built.dataset.x[:4]))
    with pytest.raises(ValueError, match="already carries"):
        model.partial_fit(built.stream,
                          labels=np.asarray(built.dataset.y[:4]))
    with pytest.raises(ValueError, match=r"\(R, N, b, d\)"):
        model.partial_fit(np.zeros(5), labels=np.zeros(5))


def test_partial_fit_default_config_inherits_provenance_graph(built):
    """With config=None, partial_fit must refine on the graph family the
    model was trained with (to_model provenance), not silently fall back
    to a random Erdos-Renyi topology."""
    model = fit(FitConfig(krr=KRR, algorithm="coke", graph="ring",
                          censor_v=0.3, censor_mu=0.99,
                          num_iters=20)).to_model()
    assert model.meta["graph"] == "ring"
    refined, res = model.partial_fit(np.asarray(built.dataset.x[:8]),
                                     labels=np.asarray(built.dataset.y[:8]))
    assert res.config.graph == "ring"
    assert res.config.algorithm == "online_coke"
    assert refined.meta["graph"] == "ring"
    assert res.history["instant_mse"].shape == (8,)
    # the FULL topology provenance carries over, not just the family name
    circ = fit(FitConfig(krr=KRR, algorithm="coke", graph="circulant",
                         graph_offsets=(1, 2), censor_v=0.3,
                         censor_mu=0.99, num_iters=10)).to_model()
    assert tuple(circ.meta["graph_offsets"]) == (1, 2)
    _, res_c = circ.partial_fit(np.asarray(built.dataset.x[:4]),
                                labels=np.asarray(built.dataset.y[:4]))
    assert res_c.config.graph == "circulant"
    assert res_c.config.graph_offsets == (1, 2)


def test_partial_fit_rejects_foreign_feature_dim(built):
    import dataclasses
    krr32 = dataclasses.replace(KRR, num_features=32)
    model = fit(FitConfig(krr=krr32, algorithm="coke", graph="ring",
                          num_iters=5)).to_model()
    with pytest.raises(ValueError, match="featurize"):
        model.partial_fit(built.stream, BASE.replace(num_iters=5))


# ---------------------------------------------------------------------------
# Satellite: core.online edge cases
# ---------------------------------------------------------------------------

def _core_stream(seed=0, R=30, N=4, b=3, D=6):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(R, N, b, D)), jnp.float32)
    labels = jnp.asarray(rng.normal(size=(R, N, b)), jnp.float32)
    adj = jnp.asarray(ring(N).adjacency, jnp.float32)
    return feats, labels, adj


def _batch_fn(feats, labels):
    return lambda k: (feats[k], labels[k])


def test_run_stream_schedule_none_matches_identity_chain():
    """schedule=None and the explicit empty Chain are the same policy —
    bit-identical trajectories, comms and bits."""
    feats, labels, adj = _core_stream()
    kw = dict(lam=1e-2, rho=0.1, lr=0.2, num_rounds=30,
              batch_fn=_batch_fn(feats, labels))
    s_none = online.init_state(4, 6)
    s_chain = online.init_state(4, 6, policy=comm_mod.Chain(()))
    out_n, mse_n, comms_n = online.run_stream(s_none, adj, None, **kw)
    out_c, mse_c, comms_c = online.run_stream(s_chain, adj,
                                              comm_mod.Chain(()), **kw)
    np.testing.assert_array_equal(np.asarray(mse_n), np.asarray(mse_c))
    np.testing.assert_array_equal(np.asarray(comms_n), np.asarray(comms_c))
    np.testing.assert_array_equal(np.asarray(out_n.theta),
                                  np.asarray(out_c.theta))
    np.testing.assert_array_equal(np.asarray(out_n.comm.bits),
                                  np.asarray(out_c.comm.bits))


def test_run_stream_comms_monotone_nondecreasing():
    feats, labels, adj = _core_stream(seed=3)
    state = online.init_state(4, 6, policy=comm_mod.Censor(0.5, 0.97))
    _, _, comms = online.run_stream(
        state, adj, comm_mod.Censor(0.5, 0.97), lam=1e-2, rho=0.1, lr=0.2,
        num_rounds=30, batch_fn=_batch_fn(feats, labels))
    c = np.asarray(comms)
    assert (np.diff(c) >= 0).all() and c[0] >= 0


def test_legacy_policy_none_state_survives_ensure_state_alignment():
    """A state built with init_state(policy=None) (empty chain, 0 stages)
    must run under a censored schedule: ensure_state re-aligns the stage
    states while the run proceeds and counts comms."""
    feats, labels, adj = _core_stream(seed=4)
    legacy = online.init_state(4, 6, policy=None)
    assert legacy.comm.stages == ()
    sched = comm_mod.Chain((comm_mod.Censor(0.3, 0.97),))
    out, mse, comms = online.run_stream(
        legacy, adj, sched, lam=1e-2, rho=0.1, lr=0.2, num_rounds=20,
        batch_fn=_batch_fn(feats, labels))
    assert len(out.comm.stages) == len(sched.stages)
    assert mse.shape == (20,)
    assert int(out.comms) == int(np.asarray(comms)[-1])
    # and a hand-rolled positional state without any comm at all
    z = jnp.zeros((4, 6), jnp.float32)
    bare = online.OnlineState(z, z, z, jnp.zeros((), jnp.int32),
                              jnp.zeros((), jnp.int32))
    assert bare.comm is None
    stepped, _ = online.stream_step(bare, feats[0], labels[0], adj, sched,
                                    lam=1e-2, rho=0.1, lr=0.2)
    assert stepped.comm is not None and stepped.comm.bits.shape == (4,)


def test_regret_decreases_on_stationary_stream(built):
    """The online protocol's sanity check: on a stationary stream the
    average regret (running mean of instantaneous MSE) decreases."""
    r = fit_stream(BASE.replace(num_iters=80), stream=built.stream)
    inst = np.asarray(r.history["instant_mse"], np.float64)
    regret = np.cumsum(inst) / np.arange(1, inst.size + 1)
    assert regret[-1] < 0.5 * regret[4]
