"""Centralized oracles: normal equations, RKHS-vs-RF consistency, d_K^lam."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rff, ridge


def _toy(L=16, N=4, T=30, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(N, T, d)).astype(np.float32)
    y = np.tanh(x.sum(-1)).astype(np.float32)
    p = rff.draw_rff(jax.random.PRNGKey(seed), d, L, 1.0)
    return rff.featurize(p, jnp.asarray(x)), jnp.asarray(y), x, y


def test_rf_ridge_satisfies_normal_equations():
    feats, labels, _, _ = _toy()
    lam = 1e-2
    theta = ridge.rf_ridge(feats, labels, lam)
    phi, y = ridge._stack_scaled(feats, labels)
    residual = phi.T @ (phi @ theta - y) + lam * theta
    np.testing.assert_allclose(np.asarray(residual), 0.0, atol=1e-4)


def test_rf_ridge_is_risk_minimizer():
    """Perturbations can't beat theta* on the regularized objective."""
    feats, labels, _, _ = _toy()
    lam = 1e-2
    theta = ridge.rf_ridge(feats, labels, lam)
    phi, y = ridge._stack_scaled(feats, labels)

    def obj(t):
        return float(jnp.sum((phi @ t - y) ** 2) + lam * jnp.sum(t * t))

    base = obj(theta)
    key = jax.random.PRNGKey(5)
    for i in range(5):
        delta = 1e-2 * jax.random.normal(jax.random.fold_in(key, i),
                                         theta.shape)
        assert obj(theta + delta) >= base - 1e-6


def test_effective_dof_bounds():
    """0 < d_K^lam < T, decreasing in lambda (Thm 3's feature-count knob)."""
    _, _, x, _ = _toy()
    X = jnp.asarray(x.reshape(-1, x.shape[-1]))
    K = rff.exact_gaussian_kernel(X, X, 1.0)
    T = K.shape[0]
    d1 = float(ridge.effective_degrees_of_freedom(K, 1e-4))
    d2 = float(ridge.effective_degrees_of_freedom(K, 1e-1))
    assert 0 < d2 < d1 < T


def test_sufficient_features_monotone_in_lambda():
    _, _, x, _ = _toy()
    X = jnp.asarray(x.reshape(-1, x.shape[-1]))
    K = rff.exact_gaussian_kernel(X, X, 1.0)
    L1 = ridge.sufficient_features(K, 1e-3)
    L2 = ridge.sufficient_features(K, 1e-1)
    assert L1 > L2 > 0
