"""MoE grouped-GShard dispatch vs dense oracle; aux loss; capacity behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.common import ModelConfig


def _cfg(E=4, k=2, shared=0, cf=8.0, group=32):
    return ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=24, vocab_size=64,
                       num_experts=E, top_k=k, num_shared_experts=shared,
                       moe_capacity_factor=cf, moe_group_size=group)


@pytest.mark.parametrize("E,k,shared", [(4, 2, 0), (8, 2, 0), (4, 2, 1),
                                        (8, 3, 2)])
def test_dispatch_matches_dense_oracle(E, k, shared):
    cfg = _cfg(E=E, k=k, shared=shared)
    params = moe.init_moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe.moe_forward(params, cfg, x)
    y_ref = moe.moe_forward_dense_ref(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-5  # >= 1 by Cauchy-Schwarz, = 1 balanced


def test_low_capacity_drops_tokens_gracefully():
    cfg = _cfg(cf=0.25)  # deliberately starved
    params = moe.init_moe_params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    y, _ = moe.moe_forward(params, cfg, x)
    y_ref = moe.moe_forward_dense_ref(params, cfg, x)
    # dropped tokens produce zeros (residual passes through in the block);
    # output must never exceed the dense result's magnitude wildly
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_ref)) + 1e-3


def test_grouping_invariance_with_ample_capacity():
    cfg_a = _cfg(group=16)
    cfg_b = _cfg(group=64)
    params = moe.init_moe_params(cfg_a, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg_a.d_model))
    ya, _ = moe.moe_forward(params, cfg_a, x)
    yb, _ = moe.moe_forward(params, cfg_b, x)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-4)


def test_gates_normalized():
    """Top-k gate values are renormalized (mixtral convention): outputs are
    convex combos, so scaling all experts by c scales output by c."""
    cfg = _cfg()
    params = moe.init_moe_params(cfg, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, cfg.d_model))
    y1, _ = moe.moe_forward(params, cfg, x)
    p2 = dict(params, w_down=params["w_down"] * 2.0)
    y2, _ = moe.moe_forward(p2, cfg, x)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), atol=1e-4)
