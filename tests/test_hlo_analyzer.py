"""The trip-count-aware HLO analyzer is the roofline's foundation — test it
on synthetic HLO snippets covering the constructs we rely on: while trip
counts, fusion exclusion, variadic tuple all-reduce operands, dot FLOPs."""
from repro.launch.hlo_analyzer import analyze_hlo

HLO_SIMPLE = """\
HloModule test

%fused_inner (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  ROOT %m = f32[8,8] multiply(%p0, %p0)
}

ENTRY %main (a: f32[8,16], b: f32[16,8]) -> f32[8,8] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,8] parameter(1)
  %d = f32[8,8] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %f = f32[8,8] fusion(%d), kind=kLoop, calls=%fused_inner
}
"""


def test_dot_flops_and_fusion_exclusion():
    res = analyze_hlo(HLO_SIMPLE)
    # dot: 2 * 8*8 * 16 = 2048 flops
    assert res["dot_flops"] == 2048.0
    # fusion internals excluded from hbm bytes; dot counts operands+output:
    # (8*16 + 16*8 + 8*8) * 4 = 1280; fusion op itself: (64 + 64) * 4 = 512
    assert res["hbm_bytes"] == 1280.0 + 512.0


HLO_WHILE = """\
HloModule test

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4] all-reduce(%d), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%add (ax: f32[], ay: f32[]) -> f32[] {
  %ax = f32[] parameter(0)
  %ay = f32[] parameter(1)
  ROOT %s = f32[] add(%ax, %ay)
}

ENTRY %main (x0: f32[4,4]) -> (s32[], f32[4,4]) {
  %x0 = f32[4,4] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%zero, %x0)
  ROOT %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""


def test_while_trip_count_multiplies_body():
    res = analyze_hlo(HLO_WHILE)
    # per-iteration dot: 2 * 16 * 4 = 128 flops; 7 trips
    assert res["dot_flops"] == 7 * 128.0
    # all-reduce operand f32[4,4] = 64 bytes, 7 trips
    assert res["collective_bytes"]["all-reduce"] == 7 * 64.0
    assert res["trip_counts"].get("body") == 7


def test_trip_count_fallback_from_condition():
    hlo = HLO_WHILE.replace(', backend_config={"known_trip_count":{"n":"7"}}',
                            "")
    res = analyze_hlo(hlo)
    assert res["dot_flops"] == 7 * 128.0  # from constant(7) in %cond


HLO_VARIADIC = """\
HloModule test

%add (ax: f32[], ay: f32[]) -> f32[] {
  %ax = f32[] parameter(0)
  %ay = f32[] parameter(1)
  ROOT %s = f32[] add(%ax, %ay)
}

ENTRY %main (a: f32[100], b: f32[50]) -> (f32[100], f32[50]) {
  %a = f32[100] parameter(0)
  %b = f32[50] parameter(1)
  ROOT %ar = (f32[100]{0}, f32[50]{0}) all-reduce(%a, %b), replica_groups={}, to_apply=%add
}
"""


def test_variadic_tuple_all_reduce_operands():
    """Tuple-typed collectives: operand bytes must come from the CALL
    parens, not the tuple-type parens (the A4/C-pair parser bug)."""
    res = analyze_hlo(HLO_VARIADIC)
    assert res["collective_bytes"]["all-reduce"] == (100 + 50) * 4.0


def test_empty_module():
    res = analyze_hlo("HloModule empty\n")
    assert res["dot_flops"] == 0.0
