"""End-to-end behaviour: training reduces loss (allreduce + COKE-DP),
decode matches forward at the model level, serving engine generates, and
checkpoints round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import restore, save
from repro.configs import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.distributed.consensus import ConsensusConfig
from repro.models import model as M
from repro.optim.optimizers import OptConfig
from repro.serve import Engine, ServeConfig
from repro.train.steps import agent_batch, make_train_step


def _stream(cfg, B=8, S=48):
    return TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                         seq_len=S, global_batch=B,
                                         structure=0.9))


def test_allreduce_training_reduces_loss():
    cfg = get_config("qwen3-1.7b").reduced()
    init_fn, step_fn, _ = make_train_step(cfg, OptConfig(lr=3e-3))
    state = init_fn(jax.random.PRNGKey(0))
    step_j = jax.jit(step_fn)
    stream = _stream(cfg)
    losses = []
    for i in range(15):
        toks, labels = stream.batch(i)
        state, m = step_j(state, {"tokens": jnp.asarray(toks),
                                  "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85


def test_coke_dp_training_reduces_loss_and_censors():
    cfg = get_config("qwen3-1.7b").reduced()
    # h(k) = 20 * 0.5^k: censors the first round or two, then transmits
    ccfg = ConsensusConfig(strategy="coke", rho=1e-3, censor_v=20.0,
                           censor_mu=0.5)
    init_fn, step_fn, _ = make_train_step(cfg, OptConfig(lr=3e-3), ccfg,
                                          num_agents=4)
    state = init_fn(jax.random.PRNGKey(0))
    step_j = jax.jit(step_fn)
    stream = _stream(cfg)
    losses, sends = [], []
    for i in range(20):
        toks, labels = stream.batch(i)
        b = agent_batch({"tokens": jnp.asarray(toks),
                         "labels": jnp.asarray(labels)}, 4)
        state, m = step_j(state, b)
        losses.append(float(m["loss"]))
        sends.append(float(m["send_frac"]))
    assert losses[-1] < losses[0] * 0.95
    # the early rounds are censored, later ones transmit
    assert min(sends) < 1.0
    assert max(sends) == 1.0
    assert int(state["consensus"]["comms"]) < 20 * 4


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "mixtral-8x7b", "zamba2-2.7b",
                                  "minicpm3-4b"])
def test_decode_matches_forward_modelwise(arch):
    """Greedy per-position logits from decode == full forward (the serve
    path is numerically the train path)."""
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # avoid capacity-drop mismatch between paths
        cfg = cfg.with_overrides(moe_capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    logits_full, _ = M.forward(params, cfg, batch)

    state = M.init_serve_state(cfg, B, cache_len=S)
    outs = []
    for t in range(S):
        lg, state = M.decode_step(params, cfg, toks[:, t:t + 1], state,
                                  jnp.asarray(t, jnp.int32))
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=2e-3)


def test_engine_generates_deterministically():
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=5, cache_len=32))
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 < cfg.vocab_size).all()


def test_engine_honors_temperature_sampling():
    """ServeConfig.greedy/temperature drive decoding: near-zero temperature
    sampling collapses to the greedy path, same key reproduces, and the
    sampled continuation actually depends on the key."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)

    greedy = Engine(cfg, params, ServeConfig(max_new_tokens=6, cache_len=32,
                                             greedy=True)).generate(prompts)
    cold = Engine(cfg, params,
                  ServeConfig(max_new_tokens=6, cache_len=32, greedy=False,
                              temperature=1e-4))
    np.testing.assert_array_equal(cold.generate(prompts), greedy)

    warm = Engine(cfg, params,
                  ServeConfig(max_new_tokens=6, cache_len=32, greedy=False,
                              temperature=5.0))
    k1, k2 = jax.random.PRNGKey(11), jax.random.PRNGKey(12)
    out1 = warm.generate(prompts, key=k1)
    np.testing.assert_array_equal(out1, warm.generate(prompts, key=k1))
    assert (out1 != warm.generate(prompts, key=k2)).any()
    assert (out1 < cfg.vocab_size).all()

    with pytest.raises(ValueError, match="temperature"):
        ServeConfig(greedy=False, temperature=0.0)


def test_engine_encdec():
    cfg = get_config("seamless-m4t-medium").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    enc = np.random.default_rng(0).normal(
        size=(2, 8, cfg.d_model)).astype(np.float32)
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, cache_len=16),
                 extra_batch={"encoder_embeds": jnp.asarray(enc)})
    out = eng.generate(np.array([[1, 2], [3, 4]], np.int32))
    assert out.shape == (2, 4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("internvl2-1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    path = str(tmp_path / "ckpt")
    save(path, params, step=7)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored, step = restore(path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    params = {"w": jnp.ones((3, 3))}
    path = str(tmp_path / "ckpt2")
    save(path, params)
    bad = {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32)}
    with pytest.raises(ValueError):
        restore(path, bad)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "minicpm3-4b", "zamba2-2.7b"])
def test_prefill_with_state_matches_decode_replay(arch):
    """The fused prefill path (one forward building all caches) must agree
    with replaying the prompt token-by-token through decode_step."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(9))
    B, S, C = 2, 9, 16
    toks = jax.random.randint(jax.random.PRNGKey(10), (B, S), 0,
                              cfg.vocab_size)

    logits_p, state_p = M.prefill_with_state(params, cfg, {"tokens": toks},
                                             cache_len=C)
    state_r = M.init_serve_state(cfg, B, cache_len=C)
    logits_r = None
    for t in range(S):
        logits_r, state_r = M.decode_step(params, cfg, toks[:, t:t + 1],
                                          state_r,
                                          jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_r),
                               atol=2e-3)
    # continuing decode from both states gives the same next-token logits
    nxt = jnp.argmax(logits_p[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
    lp, _ = M.decode_step(params, cfg, nxt, state_p,
                          jnp.asarray(S, jnp.int32))
    lr, _ = M.decode_step(params, cfg, nxt, state_r,
                          jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), atol=2e-3)
