"""Topology substrate: connectivity, incidence spectra, Thm-2 rho bound."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import graph as G


def test_er_connected_and_symmetric():
    g = G.erdos_renyi(20, 0.3, seed=0)
    assert g.is_connected()
    np.testing.assert_array_equal(g.adjacency, g.adjacency.T)
    assert np.all(np.diag(g.adjacency) == 0)


@settings(deadline=None, max_examples=15)
@given(st.integers(3, 24))
def test_ring_degree_two(n):
    g = G.ring(n)
    assert g.is_connected()
    if n > 2:
        assert np.all(g.degrees == 2)


def test_circulant_matches_ppermute_offsets():
    g = G.circulant(8, offsets=(1, 3))
    for i in range(8):
        nbrs = set(g.neighbors(i))
        assert nbrs == {(i + 1) % 8, (i - 1) % 8, (i + 3) % 8, (i - 3) % 8}


def test_incidence_shapes_and_nullspace():
    g = G.erdos_renyi(10, 0.4, seed=3)
    S_plus, S_minus = g.incidence()
    E = g.num_edges
    assert S_plus.shape == (2 * E, 10) and S_minus.shape == (2 * E, 10)
    # signed incidence annihilates the consensus (all-ones) direction
    np.testing.assert_allclose(S_minus @ np.ones(10), 0.0, atol=1e-12)
    smax, smin = g.sigma_terms()
    assert smax > 0 and smin > 0


def test_metropolis_doubly_stochastic():
    g = G.erdos_renyi(12, 0.35, seed=5)
    W = G.metropolis_weights(g)
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    assert np.all(W >= -1e-12)


def test_admissible_rho_positive():
    g = G.ring(8)
    rho = G.admissible_rho(g, m_R=0.5, M_R=2.0)
    assert rho > 0


def test_admissible_rho_raises_when_infeasible():
    g = G.ring(8)
    with pytest.raises(ValueError):
        G.admissible_rho(g, m_R=1e-9, M_R=1e3, eta3=1e6)
