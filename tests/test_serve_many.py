"""Many-model serving: `ModelRegistry` (versioned catalog, bit-identical
round-trip), `ThetaStore` (LRU paging, pinned slots, fault/writeback), and
the multi-tenant `KernelServer` (gathered bucket scoring, hot-swap
atomicity, request-lifecycle hardening).

Bit-level contract: a multi-tenant server's answer for a tagged request is
`KernelModel.score_rows(x, theta_rows)` — the gathered per-row matvec,
which is row-stable for b >= 2 (a request's rows score identically no
matter which other tenants share its padded bucket) and within float
reduction-order (~1e-6) of `KernelModel.predict`.
"""
import dataclasses
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FitConfig, KernelModel, KRRConfig, fit
from repro.serve import (KernelServeConfig, KernelServer, ModelRegistry,
                         ThetaStore)

BASE = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=30, num_features=16,
                  lam=1e-2, rho=0.5, seed=0),
    algorithm="coke", censor_v=0.5, censor_mu=0.97, num_iters=30)


@pytest.fixture(scope="module")
def base_model():
    return fit(BASE).to_model()


def variant(base: KernelModel, i: int) -> KernelModel:
    """A per-user model: the base artifact with a perturbed theta (what a
    per-user `partial_fit` refinement produces, without the fit cost)."""
    rng = np.random.default_rng(1000 + i)
    theta = np.asarray(base.theta) + rng.normal(
        scale=0.1, size=base.num_features).astype(np.float32)
    return dataclasses.replace(base, theta=jnp.asarray(theta), thetas=None)


def rowwise_ref(model: KernelModel, x: np.ndarray,
                theta) -> np.ndarray:
    """The bit-level serving reference: score_rows with x's rows all tagged
    to one theta."""
    rows = np.broadcast_to(np.asarray(theta),
                           (x.shape[0], model.num_features))
    return np.asarray(model.score_rows(x, rows))


@pytest.fixture(scope="module")
def registry8(tmp_path_factory, base_model):
    reg = ModelRegistry(str(tmp_path_factory.mktemp("registry")))
    for i in range(8):
        reg.publish(f"user-{i}", variant(base_model, i))
    return reg


@pytest.fixture(scope="module")
def queries(base_model):
    rng = np.random.default_rng(7)
    return rng.uniform(size=(64, base_model.input_dim)).astype(np.float32)


# ---------------------------------------------------------------------------
# ModelRegistry
# ---------------------------------------------------------------------------

def test_registry_publish_load_roundtrips_bit_identically(tmp_path,
                                                          base_model):
    reg = ModelRegistry(str(tmp_path))
    m = variant(base_model, 0)
    v = reg.publish("alice", m)
    assert v == 1
    loaded = reg.load("alice")
    np.testing.assert_array_equal(np.asarray(loaded.theta),
                                  np.asarray(m.theta))
    np.testing.assert_array_equal(np.asarray(loaded.rff_params.omega),
                                  np.asarray(m.rff_params.omega))
    np.testing.assert_array_equal(np.asarray(loaded.rff_params.bias),
                                  np.asarray(m.rff_params.bias))
    # identity is stamped on publish and survives the round trip
    assert loaded.model_id == "alice" and loaded.version == 1
    assert loaded.meta == m.meta
    # predictions are therefore bit-identical too
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(8, m.input_dim)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(loaded.predict(x)),
                                  np.asarray(m.predict(x)))
    # a version dir is itself a plain KernelModel artifact
    direct = KernelModel.load(reg.artifact_path("alice", 1))
    np.testing.assert_array_equal(np.asarray(direct.theta),
                                  np.asarray(m.theta))


def test_registry_versions_and_latest(tmp_path, base_model):
    reg = ModelRegistry(str(tmp_path))
    thetas = []
    for i in range(3):
        m = variant(base_model, i)
        thetas.append(np.asarray(m.theta))
        assert reg.publish("bob", m) == i + 1
    assert reg.versions("bob") == [1, 2, 3]
    assert reg.latest_version("bob") == 3
    assert reg.models() == ["bob"]
    assert "bob" in reg and "carol" not in reg
    np.testing.assert_array_equal(np.asarray(reg.load("bob").theta),
                                  thetas[2])
    np.testing.assert_array_equal(np.asarray(reg.load("bob", 2).theta),
                                  thetas[1])
    with pytest.raises(KeyError):
        reg.load("carol")
    with pytest.raises(KeyError):
        reg.load("bob", 9)
    # versions are immutable
    with pytest.raises(ValueError, match="immutable"):
        reg.publish("bob", variant(base_model, 9), version=2)


def test_registry_rejects_bad_ids(tmp_path, base_model):
    reg = ModelRegistry(str(tmp_path))
    for bad in ("", "a/b", "../up", ".hidden", "sp ace"):
        with pytest.raises(ValueError, match="model id"):
            reg.publish(bad, base_model)


# ---------------------------------------------------------------------------
# ThetaStore
# ---------------------------------------------------------------------------

def _theta(d, i):
    return np.full(d, float(i), np.float32)


def test_theta_store_lru_eviction_order():
    store = ThetaStore(3, 4)
    for name in ("a", "b", "c"):
        store.put(name, _theta(4, ord(name)))
    store.ensure("a")                      # a becomes most-recently-used
    store.put("d", _theta(4, 1))           # evicts b: the LRU entry
    assert store.resident() == ["c", "a", "d"]
    assert "b" not in store
    assert store.stats()["evictions"] == 1
    # the surviving slots still hold their exact thetas
    stack, slots, errors = store.lookup_batch(["a", "c", "d"])
    assert errors == [None, None, None]
    np.testing.assert_array_equal(np.asarray(stack[slots[0]]),
                                  _theta(4, ord("a")))


def test_theta_store_pinned_slot_protected():
    store = ThetaStore(2, 4)
    store.put("a", _theta(4, 1))
    store.put("b", _theta(4, 2))
    store.ensure("a")                      # a is MRU; b is the LRU victim...
    store.pin("b")                         # ...but pinned
    store.put("c", _theta(4, 3))           # must evict a instead
    assert "b" in store and "a" not in store
    store.pin("c")
    with pytest.raises(RuntimeError, match="pinned"):
        store.put("d", _theta(4, 4))       # every slot pinned
    store.unpin("b")
    store.put("d", _theta(4, 4))           # now b can go
    assert "d" in store and "b" not in store
    with pytest.raises(RuntimeError, match="not pinned"):
        store.unpin("b")


def test_theta_store_fault_and_dirty_writeback():
    backing = {"x": (np.full(4, 9.0, np.float32), 3)}
    published = {}

    def fault(mid):
        if mid not in backing:
            raise KeyError(mid)
        return backing[mid]

    def writeback(mid, theta, version):
        published[mid] = (np.asarray(theta), version)
        return (version or 0) + 1

    store = ThetaStore(1, 4, fault=fault, writeback=writeback)
    assert store.ensure("x") >= 0          # faulted in
    assert store.version_of("x") == 3
    assert store.stats()["faults"] == 1
    with pytest.raises(KeyError):
        store.ensure("nope")
    # a dirty resident pages back to the registry on eviction
    store.put("dirty", np.full(4, 5.0, np.float32), dirty=True)  # evicts x
    store.ensure("x")                      # evicts dirty -> writeback
    np.testing.assert_array_equal(published["dirty"][0],
                                  np.full(4, 5.0, np.float32))
    assert store.stats()["writebacks"] == 1
    # without a writeback, evicting a dirty model refuses to lose it
    lone = ThetaStore(1, 4)
    lone.put("only", np.full(4, 1.0, np.float32), dirty=True)
    with pytest.raises(RuntimeError, match="dirty"):
        lone.put("next", np.full(4, 2.0, np.float32))


def test_theta_store_shape_validation():
    store = ThetaStore(2, 4)
    with pytest.raises(ValueError, match="theta"):
        store.put("a", np.zeros(5, np.float32))
    with pytest.raises(ValueError, match="capacity"):
        ThetaStore(0, 4)


def test_theta_stack_spec_shards_feature_dim():
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import theta_stack_spec
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()  # 1x1: "model" extent 1 divides everything
    assert theta_stack_spec((8, 16), mesh) == P(None, "model")
    assert theta_stack_spec((8, 16, 3), mesh) == P(None, None, "model")


# ---------------------------------------------------------------------------
# multi-tenant KernelServer
# ---------------------------------------------------------------------------

def test_multi_tenant_gather_parity_under_paging(base_model, registry8,
                                                 queries):
    """Tagged requests through a store FORCED smaller than the tenant set:
    every answer is bit-identical to the row-wise reference with that
    tenant's registry theta, and within reduction-order of its
    `KernelModel.predict`."""
    rng = np.random.default_rng(3)
    server = KernelServer(
        model=base_model, registry=registry8,
        store=ThetaStore(4, base_model.num_features),
        config=KernelServeConfig(max_delay_ms=5.0), autostart=False)
    reqs = []
    for i in range(20):
        mid = f"user-{rng.integers(0, 8)}"
        b = int(rng.integers(2, 6))
        x = queries[:b] + np.float32(0.01) * i
        reqs.append((mid, x, server.submit(x, mid)))
    server.start()
    outs = [(mid, x, np.asarray(f.result())) for mid, x, f in reqs]
    server.stop()
    assert server.stats()["store"]["faults"] > 0  # paging actually happened
    for mid, x, out in outs:
        theta = registry8.load(mid).theta
        np.testing.assert_array_equal(out, rowwise_ref(base_model, x, theta))
        np.testing.assert_allclose(
            out, np.asarray(registry8.load(mid).predict(x)), atol=2e-6)


def test_thousand_resident_models_bit_parity(base_model, queries):
    """The acceptance-scale contract: one server, >= 1000 resident models
    in one (M, D) stack, every tagged answer bit-identical to its model's
    row-wise reference — through bucket-padded gathered device calls."""
    M, D = 1000, base_model.num_features
    rng = np.random.default_rng(11)
    thetas = rng.normal(scale=0.2, size=(M, D)).astype(np.float32)
    ids = [f"u{i:04d}" for i in range(M)]
    store = ThetaStore(1024, D)
    store.put_many(ids, thetas)
    server = KernelServer(model=base_model, store=store,
                          config=KernelServeConfig(max_delay_ms=5.0),
                          autostart=False)
    assert len(store) >= 1000
    picks = rng.integers(0, M, size=100)
    futs = [server.submit(queries[j % 32:j % 32 + 2], ids[i])
            for j, i in enumerate(picks)]
    server.start()
    outs = [np.asarray(f.result()) for f in futs]
    server.stop()
    for j, (i, out) in enumerate(zip(picks, outs)):
        x = queries[j % 32:j % 32 + 2]
        np.testing.assert_array_equal(out,
                                      rowwise_ref(base_model, x, thetas[i]))


def test_answer_independent_of_cobatched_tenants(base_model, registry8,
                                                 queries):
    """Row-stability contract: the same (x, model) request scores
    bit-identically whether it is flushed alone or coalesced into a mixed
    bucket with other tenants."""
    x = queries[:3]
    with KernelServer(model=base_model, registry=registry8,
                      config=KernelServeConfig(max_delay_ms=0.0)) as server:
        alone = np.asarray(server.predict(x, "user-3"))
    server = KernelServer(model=base_model, registry=registry8,
                          config=KernelServeConfig(max_delay_ms=5.0),
                          autostart=False)
    futs = [server.submit(queries[4 * i:4 * i + 4], f"user-{i}")
            for i in range(6)]
    probe = server.submit(x, "user-3")
    server.start()
    for f in futs:
        f.result()
    cobatched = np.asarray(probe.result())
    server.stop()
    np.testing.assert_array_equal(alone, cobatched)


def test_publish_hot_swaps_for_subsequent_requests(base_model, registry8,
                                                   queries, tmp_path):
    reg = ModelRegistry(str(tmp_path))
    reg.publish("solo", variant(base_model, 0))
    x = queries[:4]
    with KernelServer(model=base_model, registry=reg) as server:
        before = np.asarray(server.predict(x, "solo"))
        refined = np.asarray(variant(base_model, 5).theta)
        v = server.publish("solo", refined)
        assert v == 2 and reg.latest_version("solo") == 2
        after = np.asarray(server.predict(x, "solo"))
    np.testing.assert_array_equal(
        before, rowwise_ref(base_model, x, reg.load("solo", 1).theta))
    np.testing.assert_array_equal(after,
                                  rowwise_ref(base_model, x, refined))
    assert not np.array_equal(before, after)
    # the registry artifact round-trips the refined theta bit-identically
    np.testing.assert_array_equal(np.asarray(reg.load("solo").theta),
                                  refined)


def test_hot_swap_atomicity_under_fire(base_model, registry8, queries):
    """No request ever scores a torn theta: while publishes hammer one
    tenant, every concurrent answer equals EXACTLY one published version's
    reference — never a mixture — and every in-flight future resolves."""
    reg_theta = np.asarray(registry8.load("user-0").theta)
    versions = [reg_theta] + [
        reg_theta + np.float32(0.5) * (k + 1) for k in range(8)]
    x = queries[:4]
    refs = [rowwise_ref(base_model, x, th) for th in versions]
    server = KernelServer(model=base_model, registry=registry8,
                          config=KernelServeConfig(max_delay_ms=0.5))
    results, failures = [], []

    def client():
        for _ in range(30):
            try:
                results.append(np.asarray(
                    server.submit(x, "user-0").result(timeout=30)))
            except Exception as e:  # noqa: BLE001 - recorded and asserted
                failures.append(e)

    threads = [threading.Thread(target=client) for _ in range(4)]
    for t in threads:
        t.start()
    for th in versions[1:]:
        server.publish("user-0", th)
    for t in threads:
        t.join()
    server.stop()
    assert not failures
    assert len(results) == 120
    for out in results:
        assert any(np.array_equal(out, ref) for ref in refs), \
            "a served answer matched no published theta: torn read"


# ---------------------------------------------------------------------------
# request-lifecycle hardening
# ---------------------------------------------------------------------------

def test_unknown_model_fails_its_future_only(base_model, registry8,
                                             queries):
    with KernelServer(model=base_model, registry=registry8) as server:
        bad = server.submit(queries[:2], "nobody")
        with pytest.raises(KeyError, match="nobody"):
            bad.result(timeout=10)
        # the collector survived; tagged traffic keeps flowing
        out = server.predict(queries[:2], "user-1")
        np.testing.assert_array_equal(
            out, rowwise_ref(base_model, queries[:2],
                             registry8.load("user-1").theta))


def test_wrong_input_dim_raises_before_enqueue(base_model, registry8):
    with KernelServer(model=base_model, registry=registry8) as server:
        with pytest.raises(ValueError, match="queries"):
            server.submit(np.zeros((2, 99), np.float32), "user-1")
        before = server.stats()["requests"]
    assert before == 0  # the bad request never reached the queue


def test_stopped_multi_tenant_server_rejects_submissions(base_model,
                                                         registry8,
                                                         queries):
    server = KernelServer(model=base_model, registry=registry8)
    server.predict(queries[:2], "user-1")
    server.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        server.submit(queries[:2], "user-1")


def test_single_tenant_server_rejects_foreign_model_ids(base_model,
                                                        queries):
    with KernelServer(base_model) as server:
        with pytest.raises(ValueError, match="many-model"):
            server.submit(queries[:2], "someone-else")


def test_multi_tenant_construction_contracts(base_model, registry8,
                                             tmp_path):
    # publish() is a multi-tenant feature
    with KernelServer(base_model) as single:
        with pytest.raises(RuntimeError, match="multi-tenant"):
            single.publish("x", base_model.theta)
    # an empty registry cannot define the featurizer template
    with pytest.raises(ValueError, match="registry"):
        KernelServer(registry=ModelRegistry(str(tmp_path)))
    # a store sized for a different D is rejected
    with pytest.raises(ValueError, match="D="):
        KernelServer(model=base_model,
                     store=ThetaStore(4, base_model.num_features + 1))
    # a tenant fitted against a different featurizer is rejected
    other = fit(BASE.replace(
        krr=dataclasses.replace(BASE.krr, seed=123))).to_model()
    with KernelServer(model=base_model, registry=registry8) as server:
        with pytest.raises(ValueError, match="featurizer"):
            server.publish("alien", other)
