"""Theorem-level behaviour of DKLA / COKE / CTA (the paper's core claims):
convergence to the centralized optimum, linear rate, censoring savings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm, cta, graph, rff, ridge
from repro.core.censor import CensorSchedule


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    N, T, d, L = 6, 50, 3, 8
    x = rng.normal(size=(N, T, d)).astype(np.float32)
    y = (np.sin(x.sum(-1)) + 0.05 * rng.normal(size=(N, T))).astype(
        np.float32)
    g = graph.ring(N)
    p = rff.draw_rff(jax.random.PRNGKey(1), d, L, 1.0)
    feats = rff.featurize(p, jnp.asarray(x))
    labels = jnp.asarray(y)
    lam = 1e-2
    prob = admm.make_problem(feats, labels, g, lam=lam, rho=0.5)
    theta_star = ridge.rf_ridge(feats, labels, lam)
    return prob, g, theta_star


def _dist(state_theta, theta_star):
    return float(jnp.max(jnp.linalg.norm(state_theta - theta_star, axis=-1)))


def test_dkla_converges_to_centralized_optimum(problem):
    prob, _, theta_star = problem
    res = admm.run(prob, admm.dkla_schedule(), 800)
    assert _dist(res.state.theta, theta_star) < 1e-4
    assert float(res.consensus_gap[-1]) < 1e-5


def test_dkla_linear_rate(problem):
    """Theorem 1: R-linear convergence — log-distance decreases ~linearly."""
    prob, _, theta_star = problem
    res = admm.run(prob, admm.dkla_schedule(), 600)
    # distance at checkpoints shrinks by a stable factor
    d = []
    for k in (100, 200, 300, 400):
        r = admm.run(prob, admm.dkla_schedule(), k)
        d.append(_dist(r.state.theta, theta_star))
    ratios = [d[i + 1] / d[i] for i in range(3)]
    assert all(r < 0.7 for r in ratios), ratios


def test_coke_converges_and_saves_communication(problem):
    prob, _, theta_star = problem
    iters = 800
    res_d = admm.run(prob, admm.dkla_schedule(), iters)
    res_c = admm.run(prob, CensorSchedule(v=0.5, mu=0.97), iters)
    assert _dist(res_c.state.theta, theta_star) < 1e-3
    assert int(res_c.comms[-1]) < int(res_d.comms[-1])
    # final learning performance matches DKLA (paper: negligible gap)
    assert abs(float(res_c.train_mse[-1]) - float(res_d.train_mse[-1])) < 1e-5


def test_coke_zero_threshold_is_dkla(problem):
    prob, _, _ = problem
    res_d = admm.run(prob, admm.dkla_schedule(), 50)
    res_c = admm.run(prob, CensorSchedule(v=0.0, mu=0.9), 50)
    np.testing.assert_allclose(np.asarray(res_d.state.theta),
                               np.asarray(res_c.state.theta), atol=0)
    assert int(res_c.comms[-1]) == int(res_d.comms[-1])


def test_cta_converges_but_slower(problem):
    """Compare the *regularized objective* (raw MSE can dip below the
    regularized optimum's, which is not a win): at equal iteration count
    the ADMM iterate is closer to theta* than the diffusion iterate."""
    prob, g, theta_star = problem
    iters = 300
    res_cta = cta.run(prob, g, lr=0.5, num_iters=iters)
    res_dkla = admm.run(prob, admm.dkla_schedule(), iters)
    d_cta = float(jnp.max(jnp.linalg.norm(
        res_cta.state.theta - theta_star, axis=-1)))
    d_dkla = float(jnp.max(jnp.linalg.norm(
        res_dkla.state.theta - theta_star, axis=-1)))
    assert d_cta < 1.0          # CTA does converge toward theta*
    assert d_dkla <= d_cta      # ...but ADMM is closer at the same k


def test_dual_variables_sum_to_zero(problem):
    """Invariant: sum_i gamma_i == 0 for all k (symmetric graph, zero init)
    — this is what forces the fixed point to the *global* optimum."""
    prob, _, _ = problem
    res = admm.run(prob, admm.dkla_schedule(), 100)
    total = jnp.sum(res.state.gamma, axis=0)
    np.testing.assert_allclose(np.asarray(total), 0.0, atol=1e-3)


def test_censoring_more_aggressive_saves_more(problem):
    prob, _, _ = problem
    mild = admm.run(prob, CensorSchedule(v=0.1, mu=0.95), 300)
    aggressive = admm.run(prob, CensorSchedule(v=2.0, mu=0.99), 300)
    assert int(aggressive.comms[-1]) < int(mild.comms[-1])


def test_gradient_inner_solver_matches_closed_form(problem):
    """The inexact (gradient) primal update approaches the exact solve."""
    prob, _, theta_star = problem
    prob_grad = admm.Problem(prob.feats, prob.labels, prob.adjacency,
                             prob.lam, prob.rho, loss="quadratic")
    res_exact = admm.run(prob, admm.dkla_schedule(), 150)
    # force gradient path by pretending loss is non-quadratic via inner call
    state = admm.init_state(prob_grad)
    sched = admm.dkla_schedule()
    for _ in range(150):
        state = admm.coke_step(prob_grad, sched, state, chol=None,
                               inner_steps=60, inner_lr=0.4)
    d = float(jnp.max(jnp.linalg.norm(
        state.theta - res_exact.state.theta, axis=-1)))
    assert d < 0.05


def test_online_coke_stream_learns_and_censors():
    """Online (streaming) COKE — beyond-paper extension of Alg. 2 to the
    paper's stated future-work setting: instantaneous MSE on incoming data
    falls, transmissions are censored, all agents track each other."""
    import jax
    from repro.core import online, rff
    from repro.core.graph import ring

    N, b, d, L = 6, 16, 3, 24
    g = ring(N)
    p = rff.draw_rff(jax.random.PRNGKey(0), d, L, 1.0)
    true_theta = jax.random.normal(jax.random.PRNGKey(1), (L,))

    def batch_fn(k):
        kx = jax.random.fold_in(jax.random.PRNGKey(2), k)
        x = jax.random.normal(kx, (N, b, d))
        feats = rff.featurize(p, x)
        labels = jnp.einsum("nbd,d->nb", feats, true_theta)
        return feats, labels

    from repro.core.censor import CensorSchedule
    state = online.init_state(N, L)
    adjacency = jnp.asarray(g.adjacency, jnp.float32)
    state, mse, comms = online.run_stream(
        state, adjacency, CensorSchedule(0.2, 0.995), lam=1e-3, rho=0.05,
        lr=0.3, num_rounds=600, batch_fn=batch_fn)
    # instantaneous (pre-update) MSE falls by >10x
    head = float(jnp.mean(mse[:20]))
    tail = float(jnp.mean(mse[-20:]))
    assert tail < head / 10.0, (head, tail)
    # censoring saved transmissions
    assert int(comms[-1]) < 600 * N
    # consensus across the ring
    gap = float(jnp.max(jnp.linalg.norm(
        state.theta - jnp.mean(state.theta, 0, keepdims=True), axis=-1)))
    assert gap < 0.5
