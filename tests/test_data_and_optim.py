"""Data pipeline determinism + optimizer correctness properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.data.synthetic import UCI_SPECS, paper_synthetic, uci_standin
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.optim.optimizers import (OptConfig, apply_updates,
                                    init_opt_state, opt_update)


def test_paper_synthetic_matches_protocol():
    ds = paper_synthetic(num_agents=20, samples_per_agent=100)
    assert ds.num_agents == 20 and ds.input_dim == 5
    assert ds.x.min() >= 0.0 and ds.x.max() <= 1.0          # normalized
    assert ds.x.shape[1] == 70 and ds.x_test.shape[1] == 30  # 70/30 split


def test_uci_standins_match_published_dims():
    for name, (total, dim) in UCI_SPECS.items():
        ds = uci_standin(name, num_agents=10, subsample=500)
        assert ds.input_dim == dim, name
        assert ds.num_agents == 10


def test_token_stream_deterministic_and_sharded():
    cfg = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=4,
                            seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    t1, l1 = s1.batch(5)
    t2, l2 = s2.batch(5)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert t1.max() < 100 and t1.min() >= 0
    # labels are next tokens
    np.testing.assert_array_equal(l1[:, :-1], t1[:, 1:])


def test_token_stream_learnable_structure():
    cfg = TokenStreamConfig(vocab_size=50, seq_len=64, global_batch=4,
                            structure=1.0)
    toks, _ = TokenStream(cfg).batch(0)
    nxt = (toks[:, :-1].astype(np.int64) * 31 + 7) % 50
    np.testing.assert_array_equal(toks[:, 1:], nxt.astype(np.int32))


def _quad(x):
    return jnp.sum((x - 3.0) ** 2)


def test_adamw_minimizes_quadratic():
    cfg = OptConfig(kind="adamw", lr=0.1)
    params = {"x": jnp.zeros(4)}
    state = init_opt_state(cfg, params)
    for _ in range(300):
        g = jax.grad(lambda p: _quad(p["x"]))(params)
        upd, state = opt_update(cfg, g, state, params)
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["x"]), 3.0, atol=1e-2)


def test_sgd_momentum_minimizes_quadratic():
    cfg = OptConfig(kind="sgd", lr=0.05, momentum=0.9)
    params = {"x": jnp.zeros(4)}
    state = init_opt_state(cfg, params)
    for _ in range(200):
        g = jax.grad(lambda p: _quad(p["x"]))(params)
        upd, state = opt_update(cfg, g, state, params)
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["x"]), 3.0, atol=1e-2)


@settings(deadline=None, max_examples=20)
@given(st.floats(0.1, 5.0))
def test_grad_clip_bounds_update(clip):
    cfg = OptConfig(kind="sgd", lr=1.0, grad_clip=clip)
    params = {"x": jnp.zeros(3)}
    state = init_opt_state(cfg, params)
    g = {"x": jnp.array([100.0, -100.0, 50.0])}
    upd, _ = opt_update(cfg, g, state, params)
    norm = float(jnp.linalg.norm(upd["x"]))
    assert norm <= clip * 1.01


def test_weight_decay_shrinks_params():
    cfg = OptConfig(kind="adamw", lr=0.1, weight_decay=0.1)
    params = {"x": jnp.full((3,), 10.0)}
    state = init_opt_state(cfg, params)
    g = {"x": jnp.zeros(3)}
    upd, _ = opt_update(cfg, g, state, params)
    assert float(jnp.max(upd["x"])) < 0.0
