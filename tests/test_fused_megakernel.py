"""Conformance battery for the fused ADMM megakernel (`coke_megastep`):
bit-parity against the blockwise reference across shapes, the pad-tail/
xi_sq contract pins, fused-vs-simulator fit parity under identity and
Censor+Quantize chains, the degenerate-gossip pin on the fused path, a
jaxpr inspection pinning exactly ONE `pallas_call` per fused iteration,
the top-k participation slowdown regression, and the interpret-mode
resolver contract (`repro.kernels.runtime.resolve_interpret`)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (assert_fit_parity, assert_gossip_degenerate,
                      assert_results_match)

from repro.api import (Censor, Chain, FitConfig, KRRConfig, Quantize,
                       build_problem, fit, get_solver)
from repro.api import backends
from repro.api.config import SolveContext
from repro.core.gossip import GossipPlan
from repro.core.step import participation_mask
from repro.kernels import runtime
from repro.kernels.coke_update.coke_update import (coke_fused_update,
                                                  coke_megastep,
                                                  megastep_launch_params)
from repro.kernels.coke_update.ops import coke_update_pytree
from repro.kernels.coke_update.ref import coke_megastep_ref

KRR = KRRConfig(num_agents=4, samples_per_agent=40, num_features=32,
                lam=1e-2, rho=0.1, seed=0)
BASE = FitConfig(krr=KRR, graph="ring", algorithm="coke", censor_v=0.3,
                 censor_mu=0.97, num_iters=40, primal="gradient",
                 inner_steps=1, inner_lr=0.05)


def _operands(n, t, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    theta = jax.random.normal(ks[0], (n, d), jnp.float32)
    hat = jax.random.normal(ks[1], (n, d), jnp.float32)
    gamma = 0.1 * jax.random.normal(ks[2], (n, d), jnp.float32)
    phi = jax.random.normal(ks[3], (n, t, d), jnp.float32)
    y = jax.random.normal(ks[4], (n, t), jnp.float32)
    return theta, hat, gamma, phi, y


# ---------------------------------------------------------------------------
# megakernel vs blockwise bit reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,t,d,offsets,bt", [
    (4, 40, 32, (1,), None),      # the fit-level shape
    (2, 33, 513, (1,), 8),        # T and D both off-tile
    (8, 64, 100, (1, 2), None),   # non-multiple-of-128 D, circulant deg 4
    (3, 17, 128, (1,), 8),        # exact lane tile, ragged T
    (5, 128, 256, (2,), 32),      # non-unit ring offset
], ids=["fit", "ragged", "circulant", "lane", "offset2"])
def test_megastep_bitwise_vs_reference(n, t, d, offsets, bt):
    """The pallas megakernel and `ref.coke_megastep_ref` (same block walk,
    jitted so XLA rounds its dots identically) agree BITWISE."""
    theta, hat, gamma, phi, y = _operands(n, t, d)
    out_k, xi_k = coke_megastep(theta, hat, gamma, phi, y, rho=0.3,
                                lam=1e-2, lr=0.05, offsets=offsets,
                                block_t=bt, interpret=True)
    out_r, xi_r = coke_megastep_ref(theta, hat, gamma, phi, y, rho=0.3,
                                    lam=1e-2, lr=0.05, offsets=offsets,
                                    block_t=bt)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(xi_k), np.asarray(xi_r))


def test_megastep_launch_params_roofline():
    """Block sizing respects the VMEM budget and the launch carries its
    own roofline verdict (derived from launch.analysis)."""
    lp = megastep_launch_params(8, 1000, 4096, 2)
    assert lp.block_t % 8 == 0 and lp.padded_d % 128 == 0
    assert lp.padded_t % lp.block_t == 0 and lp.padded_t >= 1000
    streamed = 2 * (lp.block_t * lp.padded_d * 4 + lp.block_t * 4)
    resident = (5 + 2) * lp.padded_d * 4
    assert streamed + resident <= 8 * 1024 * 1024
    assert lp.roofline["dominant"] in ("compute", "memory")
    assert lp.roofline["step_s_lower_bound"] > 0


# ---------------------------------------------------------------------------
# pad-tail / xi_sq contract (satellite: docstring reconciliation pins)
# ---------------------------------------------------------------------------

def test_megastep_pad_tail_contributes_zero():
    """Non-multiple-of-128 D: the lane pad must contribute EXACTLY zero —
    explicitly zero-padding the operands to the tile boundary is bitwise
    the same call, the padded columns of theta_new are exactly 0.0, and
    xi_sq equals the dense ||theta_new - theta_hat||^2."""
    n, t, d, dp = 3, 24, 200, 256
    theta, hat, gamma, phi, y = _operands(n, t, d, seed=1)
    kw = dict(rho=0.3, lam=1e-2, lr=0.05, offsets=(1,), block_t=8,
              interpret=True)
    out, xi = coke_megastep(theta, hat, gamma, phi, y, **kw)

    padr = lambda a: jnp.pad(a, ((0, 0), (0, dp - d)))
    out_p, xi_p = coke_megastep(padr(theta), padr(hat), padr(gamma),
                                jnp.pad(phi, ((0, 0), (0, 0), (0, dp - d))),
                                y, **kw)
    np.testing.assert_array_equal(np.asarray(out_p[:, :d]), np.asarray(out))
    np.testing.assert_array_equal(np.asarray(out_p[:, d:]),
                                  np.zeros((n, dp - d), np.float32))
    np.testing.assert_array_equal(np.asarray(xi_p), np.asarray(xi))
    dense = jnp.sum((out - hat) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(xi), np.asarray(dense), rtol=1e-6)


def test_fused_update_pad_tail_contributes_zero():
    """Same pin for the consensus-combine kernel at D=513 (one element
    past the 512 block): xi_sq is the squared censor norm over the REAL
    entries only."""
    n, d = 4, 513
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    ops6 = [jax.random.normal(k, (n, d), jnp.float32) for k in ks]
    gaug, xi = coke_fused_update(*ops6, rho=0.5, deg=2.0, interpret=True)

    padded = [jnp.pad(a, ((0, 0), (0, 1024 - d))) for a in ops6]
    gaug_p, xi_p = coke_fused_update(*padded, rho=0.5, deg=2.0,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(gaug_p[:, :d]),
                                  np.asarray(gaug))
    np.testing.assert_array_equal(np.asarray(xi_p), np.asarray(xi))
    theta, hat = ops6[0], ops6[1]
    dense = jnp.sum((hat - theta) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(xi), np.asarray(dense), rtol=1e-6)


def test_pytree_wrapper_returns_sqrt_of_kernel_xi_sq():
    """The two-level xi contract: kernels emit xi_sq (partial-sum
    friendly), `coke_update_pytree` emits xi_norm = sqrt(xi_sq) — the
    quantity the censor policy thresholds."""
    n = 5
    ks = jax.random.split(jax.random.PRNGKey(4), 12)
    mk = lambda i: {"a": jax.random.normal(ks[2 * i], (n, 3), jnp.float32),
                    "b": jax.random.normal(ks[2 * i + 1], (n, 5),
                                           jnp.float32)}
    trees = [mk(i) for i in range(6)]
    _, xi_norm = coke_update_pytree(*trees, rho=0.5, interpret=True)
    flat = [jnp.concatenate([t["a"], t["b"]], axis=1) for t in trees]
    _, xi_sq = coke_fused_update(*flat, rho=0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(xi_norm),
                                  np.asarray(jnp.sqrt(xi_sq)))


# ---------------------------------------------------------------------------
# fit-level conformance (megakernel substituted into the StepProgram)
# ---------------------------------------------------------------------------

CENSOR_QUANT = Chain([Censor(0.3, 0.97), Quantize(bits=5, seed=7)])


@pytest.mark.parametrize("alg", ["dkla", "coke"])
@pytest.mark.parametrize("chain", [Chain(()), CENSOR_QUANT],
                         ids=["identity", "censor+quantize"])
def test_fused_megakernel_matches_simulator(alg, chain):
    """fused (megakernel) vs simulator: identical comm decisions and bit
    accounting, theta to 1e-5 — for DKLA and COKE, under the identity
    chain and a Censor+Quantize policy."""
    cfg = BASE.replace(algorithm=alg, comm=chain, censor_v=None,
                       censor_mu=None)
    assert_fit_parity(cfg, ("simulator", "fused"), exact=("comms", "bits"),
                      theta_atol=1e-5)


def test_fused_gossip_degenerate():
    """participation=1.0 gossip on the fused megakernel path is bitwise
    the synchronous run (the all-true mask selects every row)."""
    assert_gossip_degenerate(BASE, ("fused",))


MEGA_CONFIGS = {
    "coke-censor": BASE,
    "dkla": BASE.replace(algorithm="dkla"),
    "gossip": BASE.replace(exec="gossip", participation=0.6),
    "circulant2": BASE.replace(
        krr=dataclasses.replace(KRR, num_agents=6), graph="circulant",
        graph_offsets=(1, 2)),
}


@pytest.mark.parametrize("name", sorted(MEGA_CONFIGS), ids=str)
def test_megakernel_bitwise_vs_unfused_stepprogram(name, monkeypatch):
    """The acceptance pin: the fused megakernel iteration is BIT-IDENTICAL
    to the unfused StepProgram path (same stage assembly, blockwise
    reference instead of the pallas_call) over a whole fit — every history
    key and the final theta, exact."""
    cfg = MEGA_CONFIGS[name].replace(backend="fused")
    res_kernel = fit(cfg)
    monkeypatch.setattr(backends, "_MEGASTEP_USE_KERNEL", False)
    res_unfused = fit(cfg)
    assert_results_match(res_kernel, res_unfused, exact="*",
                         err=f"megakernel vs unfused ({name})")


def _count_pallas_calls(jaxpr) -> int:
    def subs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            return [v.jaxpr]
        if isinstance(v, jax.core.Jaxpr):
            return [v]
        if isinstance(v, (tuple, list)):
            return [j for x in v for j in subs(x)]
        return []
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            n += sum(_count_pallas_calls(j) for j in subs(v))
    return n


def _fused_iteration_jaxpr():
    cfg = BASE.replace(backend="fused")
    problem = build_problem(cfg).problem
    ctx = SolveContext.from_config(cfg, num_agents=problem.num_agents)
    carry0, chunk_fn, _ = backends.consensus_runner(
        cfg, get_solver(cfg.algorithm), problem, ctx, None)
    return jax.make_jaxpr(lambda c: chunk_fn(c, 1))(carry0).jaxpr


def test_fused_iteration_has_exactly_one_pallas_call(monkeypatch):
    """The megakernel really is a MEGAkernel: one fused iteration lowers
    to exactly ONE pallas_call (RFF application + primal + ring combine +
    censor partial sums), and zero with the kernel substitution off."""
    assert _count_pallas_calls(_fused_iteration_jaxpr()) == 1
    monkeypatch.setattr(backends, "_MEGASTEP_USE_KERNEL", False)
    assert _count_pallas_calls(_fused_iteration_jaxpr()) == 0


# ---------------------------------------------------------------------------
# participation_mask: top-k slowdown regression (satellite fix)
# ---------------------------------------------------------------------------

def _masks(plan, rounds=200, n=8):
    key = jax.random.PRNGKey(3)
    return np.asarray([participation_mask(key, k, n, plan)
                       for k in range(1, rounds + 1)])


def test_topk_slowdown_threads_into_ranking():
    """Regression: fixed-size (top-k) sampling used to IGNORE straggler
    slowdowns — a 1e6x-slowed agent fired at the base 3/8 rate. Slowdown
    now scales the ranking score, so the straggler sinks while exactly
    `size` agents still fire each round."""
    slow = jnp.ones(8).at[0].set(1e6)
    m = _masks(GossipPlan(participation=jnp.float32(1.0), size=3,
                          slowdown=slow))
    assert (m.sum(axis=1) == 3).all()
    assert m[:, 0].sum() == 0
    others = m[:, 1:].sum(axis=0)
    assert (others > 0).all()          # the load redistributes


def test_topk_slowdown_none_bitwise_matches_unit():
    """slowdown=None is bit-identical to an all-ones slowdown (the score
    is the raw uniform draw either way) — common-random-numbers pin."""
    none = _masks(GossipPlan(participation=jnp.float32(1.0), size=3,
                             slowdown=None), rounds=60)
    unit = _masks(GossipPlan(participation=jnp.float32(1.0), size=3,
                             slowdown=jnp.ones(8)), rounds=60)
    np.testing.assert_array_equal(none, unit)


def test_topk_slowdown_respects_liveness():
    """Dead rows score +inf: never selected even against huge slowdowns,
    and the mask still fires exactly `size` live agents."""
    slow = jnp.full((8,), 1e6).at[0].set(1.0)
    alive = jnp.ones(8, bool).at[0].set(False)
    key = jax.random.PRNGKey(5)
    plan = GossipPlan(participation=jnp.float32(1.0), size=3, slowdown=slow)
    m = np.asarray([participation_mask(key, k, 8, plan, alive)
                    for k in range(1, 40)])
    assert (~m[:, 0]).all()
    assert (m.sum(axis=1) == 3).all()


# ---------------------------------------------------------------------------
# interpret-mode resolution
# ---------------------------------------------------------------------------

def test_resolve_interpret_defaults_to_backend(monkeypatch):
    monkeypatch.delenv(runtime._ENV_VAR, raising=False)
    assert runtime.resolve_interpret(None) is (
        jax.default_backend() == "cpu")
    assert runtime.resolve_interpret(None) is True  # suite runs on CPU


@pytest.mark.parametrize("raw,expect", [
    ("1", True), ("true", True), ("ON", True), (" yes ", True),
    ("0", False), ("false", False), ("Off", False), ("no", False),
])
def test_resolve_interpret_env_override(monkeypatch, raw, expect):
    monkeypatch.setenv(runtime._ENV_VAR, raw)
    assert runtime.resolve_interpret(None) is expect


def test_resolve_interpret_rejects_garbage_env(monkeypatch):
    monkeypatch.setenv(runtime._ENV_VAR, "maybe")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        runtime.resolve_interpret(None)


def test_resolve_interpret_explicit_argument_wins(monkeypatch):
    monkeypatch.setenv(runtime._ENV_VAR, "1")
    assert runtime.resolve_interpret(False) is False
    monkeypatch.setenv(runtime._ENV_VAR, "0")
    assert runtime.resolve_interpret(True) is True
