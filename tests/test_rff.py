"""RFF mapping: kernel approximation quality, common-seed consistency,
norm bounds (used by the convergence proof), both real-valued mappings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import rff


@pytest.mark.parametrize("mapping", ["cos_bias", "cos_sin"])
def test_kernel_approximation_improves_with_L(mapping):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 5))
    exact = rff.exact_gaussian_kernel(x, x, bandwidth=1.0)
    errs = []
    for L in (32, 512):
        p = rff.draw_rff(jax.random.PRNGKey(2), 5, L, 1.0, mapping=mapping)
        approx = rff.approx_kernel(p, x, x)
        errs.append(float(jnp.max(jnp.abs(approx - exact))))
    assert errs[1] < errs[0]
    assert errs[1] < 0.25


def test_common_seed_gives_identical_features():
    pa = rff.draw_rff(jax.random.PRNGKey(7), 3, 64, 2.0)
    pb = rff.draw_rff(jax.random.PRNGKey(7), 3, 64, 2.0)
    np.testing.assert_array_equal(np.asarray(pa.omega), np.asarray(pb.omega))
    np.testing.assert_array_equal(np.asarray(pa.bias), np.asarray(pb.bias))


def test_unbiasedness_cos_bias():
    """E[phi(x)'phi(y)] -> kappa(x,y) over feature draws."""
    x = jnp.array([[0.3, -0.2]])
    y = jnp.array([[-0.1, 0.5]])
    exact = float(rff.exact_gaussian_kernel(x, y, 1.0)[0, 0])
    p = rff.draw_rff(jax.random.PRNGKey(3), 2, 20000, 1.0)
    approx = float(rff.approx_kernel(p, x, y)[0, 0])
    assert abs(approx - exact) < 0.05


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 40), st.integers(2, 8),
       st.floats(0.5, 4.0))
def test_cos_sin_norm_exactly_one(T, d, bw):
    """||phi_L(x)||_2 == 1 for the (12) mapping — the bound in Eq. (33)."""
    p = rff.draw_rff(jax.random.PRNGKey(11), d, 32, bw, mapping="cos_sin")
    x = jax.random.normal(jax.random.PRNGKey(T), (T, d))
    norms = jnp.sum(rff.featurize(p, x) ** 2, -1)
    np.testing.assert_allclose(np.asarray(norms), 1.0, atol=1e-5)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 40), st.integers(2, 8))
def test_cos_bias_norm_bounded(T, d):
    """||phi_L(x)||^2 <= 2 for the (13) mapping."""
    p = rff.draw_rff(jax.random.PRNGKey(13), d, 64, 1.0, mapping="cos_bias")
    x = jax.random.normal(jax.random.PRNGKey(T + 100), (T, d))
    norms = jnp.sum(rff.featurize(p, x) ** 2, -1)
    assert float(jnp.max(norms)) <= 2.0 + 1e-5


def test_feature_dims():
    p12 = rff.draw_rff(jax.random.PRNGKey(0), 4, 64, 1.0, mapping="cos_sin")
    p13 = rff.draw_rff(jax.random.PRNGKey(0), 4, 64, 1.0, mapping="cos_bias")
    x = jnp.ones((3, 4))
    assert rff.featurize(p12, x).shape == (3, 64)
    assert rff.featurize(p13, x).shape == (3, 64)
    assert p12.num_features == 64 and p12.omega.shape == (4, 32)
    assert p13.num_features == 64 and p13.omega.shape == (4, 64)
