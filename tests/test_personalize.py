"""The personalization subsystem: learned-graph invariants (property
tests), the two-phase prefix-invariance pin (iterations before the first
graph update are bit-identical to the static-topology run), cross-backend
personalized parity, degenerate-gossip composition, the per-agent serving
path (to_models / ckpt round-trip / registry publish), the clustered
non-IID generator, and the validation surface."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_gossip_degenerate
from hypothesis_compat import given, hnp, settings, st

from repro.api import (FitConfig, KernelModel, KRRConfig, Personalization,
                       build_problem, fit, fit_stream, heterogeneous, sweep)
from repro.core import personalize as P

# small clustered workload shared by the fit-level tests; censor_v=0 means
# every agent transmits every iteration (equal-bits across arms)
KRR = KRRConfig(dataset="heterogeneous", num_agents=12, samples_per_agent=60,
                num_tasks=3, num_features=32, lam=1e-3, rho=0.1,
                censor_v=0.3, censor_mu=0.97, seed=0)
BASE = FitConfig(krr=KRR, graph="ring", num_iters=40, primal="cg")
PZ = Personalization(k=3, every=5, warmup=15)


# ---------------------------------------------------------------------------
# Learned-graph invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(thetas=hnp.arrays(np.float32, (9, 7),
                         elements=st.floats(-5.0, 5.0, width=32)),
       k=st.integers(1, 4),
       affinity=st.sampled_from(("rbf", "cosine")),
       scale=st.sampled_from((0.0, 0.5, 2.0)))
def test_adjacency_invariants(thetas, k, affinity, scale):
    """Any theta stack yields a symmetric, self-loop-free adjacency with
    row degree <= k and weights in [0, 1]."""
    pz = Personalization(k=k, affinity=affinity, scale=scale)
    A = np.asarray(P.learned_adjacency(pz, jnp.asarray(thetas)))
    np.testing.assert_array_equal(A, A.T, err_msg="not symmetric")
    np.testing.assert_array_equal(np.diag(A), 0.0, err_msg="self loops")
    assert int(np.max(np.sum(A > 0, axis=1))) <= k
    assert float(A.min()) >= 0.0 and float(A.max()) <= 1.0 + 1e-6


def test_topk_rejects_bad_k():
    th = jnp.ones((6, 4))
    with pytest.raises(ValueError):
        P.topk_neighbors(th, 0)
    with pytest.raises(ValueError):
        P.topk_neighbors(th, 6)


def test_clustered_thetas_recover_clusters():
    """Well-separated per-cluster thetas produce a graph whose edge mass
    is entirely intra-cluster (graph_recovery == 1)."""
    rng = np.random.default_rng(0)
    clusters = np.arange(12) % 3
    centers = 10.0 * rng.normal(size=(3, 16))
    thetas = centers[clusters] + 0.1 * rng.normal(size=(12, 16))
    A = P.learned_adjacency(Personalization(k=3),
                            jnp.asarray(thetas, jnp.float32))
    assert float(P.graph_recovery(A, clusters)) == 1.0


def test_update_cadence():
    """First refresh lands at iteration warmup+1, then every `every`."""
    pz = Personalization(k=2, every=5, warmup=10)
    ks = [k for k in range(1, 31) if bool(P.should_update(pz, k))]
    assert ks == [11, 16, 21, 26]


# ---------------------------------------------------------------------------
# The prefix-invariance pin (the two-phase driver's contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["simulator", "spmd"])
def test_prefix_bit_identical_to_static(backend):
    """A personalized run whose warmup covers every iteration IS the
    static run, bit for bit: the warmup phase executes the literal
    static-consensus program (same primal mode), not a cond-gated variant
    of it."""
    cfg = BASE.replace(backend=backend)
    stat = fit(cfg)
    warm = fit(cfg.replace(
        personalization=Personalization(k=3, every=5, warmup=100)))
    for k in stat.history:
        np.testing.assert_array_equal(
            np.asarray(stat.history[k]), np.asarray(warm.history[k]),
            err_msg=f"{backend}:{k}")
    np.testing.assert_array_equal(np.asarray(stat.theta),
                                  np.asarray(warm.theta),
                                  err_msg=f"{backend}:theta")
    # the all-warmup run still reports the per-agent trajectory and ends
    # holding the (never-refreshed) starting graph
    assert "per_agent_mse" in warm.history
    assert warm.learned_adjacency is not None
    assert stat.learned_adjacency is None


def test_refreshing_run_prefix_and_divergence():
    """A run that DOES refresh matches the static run bit-for-bit up to
    its warmup boundary and diverges after it."""
    stat = fit(BASE)
    pers = fit(BASE.replace(personalization=PZ))
    w = PZ.warmup
    mse_s = np.asarray(stat.history["train_mse"])
    mse_p = np.asarray(pers.history["train_mse"])
    np.testing.assert_array_equal(mse_p[:w], mse_s[:w])
    assert float(np.max(np.abs(mse_p[w:] - mse_s[w:]))) > 0.0
    A = np.asarray(pers.learned_adjacency)
    np.testing.assert_array_equal(A, A.T)
    np.testing.assert_array_equal(np.diag(A), 0.0)
    assert int(np.max(np.sum(A > 0, axis=1))) <= PZ.k


def test_chunked_crosses_phase_boundary():
    """Chunked execution whose chunk edges straddle the warmup->live
    handoff is bit-identical to the monolithic run."""
    mono = fit(BASE.replace(personalization=PZ))
    chunked = fit(BASE.replace(personalization=PZ, chunk_size=7))
    for k in mono.history:
        np.testing.assert_array_equal(np.asarray(mono.history[k]),
                                      np.asarray(chunked.history[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(mono.theta),
                                  np.asarray(chunked.theta))


# ---------------------------------------------------------------------------
# Cross-backend + gossip composition
# ---------------------------------------------------------------------------

def test_sim_spmd_personalized_parity():
    """Simulator and spmd learn the SAME graph (exact support) and
    float-close trajectories. Theta is pinned relatively: cg drift is
    amplified through the refresh's discontinuous top-k, so the absolute
    static tolerance does not transfer."""
    sim = fit(BASE.replace(personalization=PZ))
    spmd = fit(BASE.replace(personalization=PZ, backend="spmd"))
    As, Ap = np.asarray(sim.learned_adjacency), \
        np.asarray(spmd.learned_adjacency)
    np.testing.assert_array_equal(As > 0, Ap > 0)
    np.testing.assert_allclose(As, Ap, atol=1e-3)
    d = float(jnp.max(jnp.abs(sim.theta - spmd.theta)))
    assert d / float(jnp.max(jnp.abs(sim.theta))) < 1e-3
    # a censor decision may flip under that drift — never by more than a
    # round of transmissions
    assert float(np.max(np.abs(
        np.asarray(sim.history["comms"], np.int64)
        - np.asarray(spmd.history["comms"], np.int64)))) <= KRR.num_agents


def test_degenerate_gossip_personalized():
    """participation=1.0 gossip == sync, bit-for-bit, WITH a live learned
    graph — the dense masked step collapses to the dense sync step."""
    assert_gossip_degenerate(BASE.replace(personalization=PZ),
                             ("simulator", "spmd"))


def test_streaming_personalized():
    """fit_stream: same prefix pin, and the spmd stream path agrees."""
    cfg = BASE.replace(algorithm="online_coke", num_iters=30,
                       primal="auto", online_batch=6, online_lr=0.3,
                       personalization=Personalization(k=2, every=4,
                                                       warmup=10))
    stat = fit_stream(cfg.replace(personalization=None))
    sim = fit_stream(cfg)
    pre = np.asarray(sim.history["instant_mse"][:10])
    np.testing.assert_array_equal(
        pre, np.asarray(stat.history["instant_mse"][:10]))
    assert sim.learned_adjacency is not None
    spmd = fit_stream(cfg.replace(backend="spmd"))
    d = float(jnp.max(jnp.abs(sim.theta - spmd.theta)))
    assert d / max(float(jnp.max(jnp.abs(sim.theta))), 1e-9) < 1e-3


# ---------------------------------------------------------------------------
# Personalization-aware sweeps (the phased program, vmapped per cell)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warmup", [0, 8, 100],
                         ids=["no-warmup", "mid-run", "all-warmup"])
def test_sweep_personalized_matches_individual_fits(warmup):
    """sweep() replays fit()'s phased warmup->live program inside each
    vmapped lane, at every phase-boundary placement: before the first
    iteration (warmup=0: the live program from the start), mid-run (the
    carry handoff crosses inside the scan), and past the end (warmup >=
    num_iters: a zero-length live phase that still attaches the graph).
    Per-cell comms/bits are bit-identical to the individual personalized
    fit; thetas agree to vmap-reassociation tolerance (loose: the
    refresh's discontinuous top-k amplifies float drift, as in the
    sim-vs-spmd parity pin above)."""
    pz = Personalization(k=3, every=5, warmup=warmup)
    base = BASE.replace(num_iters=20, personalization=pz)
    cells = [(0.3, 0.97), (0.5, 0.95)]
    sw = sweep(base, cells)
    for i, (v, mu) in enumerate(cells):
        r = fit(base.replace(censor_v=v, censor_mu=mu))
        for k in ("comms", "bits"):
            np.testing.assert_array_equal(
                np.asarray(sw.history[k][i]), np.asarray(r.history[k]),
                err_msg=f"cell{i}:{k}")
        np.testing.assert_allclose(np.asarray(sw.thetas[i]),
                                   np.asarray(r.theta), atol=1e-3,
                                   err_msg=f"cell{i}:theta")


def test_sweep_all_warmup_equals_static_sweep():
    """A personalized sweep whose warmup covers every iteration pins the
    prefix contract under vmap: its shared history keys are bit-identical
    to the personalization=None sweep (the warmup lanes run the literal
    static program)."""
    cells = [(0.3, 0.97), (0.5, 0.95)]
    stat = sweep(BASE.replace(num_iters=15), cells)
    warm = sweep(BASE.replace(num_iters=15, personalization=Personalization(
        k=3, every=5, warmup=50)), cells)
    for k in stat.history:
        np.testing.assert_array_equal(np.asarray(stat.history[k]),
                                      np.asarray(warm.history[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(stat.thetas),
                                  np.asarray(warm.thetas))


# ---------------------------------------------------------------------------
# Per-agent serving path
# ---------------------------------------------------------------------------

def test_to_model_raises_to_models_roundtrips(tmp_path):
    res = fit(BASE.replace(personalization=PZ))
    with pytest.raises(ValueError, match="personalized"):
        res.to_model()
    models = res.to_models()
    assert len(models) == KRR.num_agents
    for i, m in enumerate(models):
        assert m.meta["agent"] == i
        assert m.meta["personalization"]["k"] == PZ.k
    # ckpt round-trip: agent 5's model predicts identically after reload
    x = np.random.default_rng(3).uniform(size=(7, 5)).astype(np.float32)
    path = str(tmp_path / "agent5")
    models[5].save(path)
    reloaded = KernelModel.load(path)
    np.testing.assert_array_equal(np.asarray(models[5].predict(x)),
                                  np.asarray(reloaded.predict(x)))
    np.testing.assert_array_equal(np.asarray(models[5].theta),
                                  np.asarray(reloaded.theta))


def test_publish_models_into_registry(tmp_path):
    from repro.serve.registry import ModelRegistry

    res = fit(BASE.replace(personalization=PZ))
    reg = ModelRegistry(str(tmp_path / "registry"))
    published = res.publish_models(reg, prefix="pz")
    assert [mid for mid, _ in published] == \
        [f"pz-{i:03d}" for i in range(KRR.num_agents)]
    got = reg.load("pz-004")
    np.testing.assert_array_equal(np.asarray(got.theta),
                                  np.asarray(res.theta[4]))
    assert got.meta["agent"] == 4


# ---------------------------------------------------------------------------
# Clustered non-IID generator + end-to-end personalization win
# ---------------------------------------------------------------------------

def test_heterogeneous_generator():
    ds = heterogeneous(num_agents=9, num_tasks=3, samples_per_agent=40,
                       seed=1)
    assert ds.x.shape == (9, 28, 5) and ds.x_test.shape == (9, 12, 5)
    np.testing.assert_array_equal(ds.cluster, np.arange(9) % 3)
    assert ds.num_tasks == 3
    assert float(ds.x.min()) >= 0.0 and float(ds.x.max()) <= 1.0
    # same-cluster agents share a target function: their label
    # distributions match far better across than between clusters
    with pytest.raises(ValueError):
        heterogeneous(num_agents=4, num_tasks=5)


def test_built_problem_carries_clusters():
    built = build_problem(BASE)
    np.testing.assert_array_equal(built.clusters,
                                  np.arange(KRR.num_agents) % 3)
    assert build_problem(BASE.replace(
        krr=dataclasses.replace(KRR, dataset="synthetic"))).clusters is None


def test_personalized_beats_consensus_and_recovers_clusters():
    """The acceptance experiment in miniature: on clustered non-IID data
    the personalized fit beats full consensus on mean per-agent test MSE
    at equal cumulative bits, and the learned graph is intra-cluster."""
    # rho=0.01: the proximity coupling must be weak enough for per-cluster
    # structure to emerge in theta space (rho=0.1 over-mixes the agents
    # and the affinities see only noise)
    cfg = BASE.replace(num_iters=120,
                       krr=dataclasses.replace(KRR, censor_v=0.0,
                                               rho=0.01))
    built = build_problem(cfg)
    cons = fit(cfg, problem=built.problem)
    pers = fit(cfg.replace(personalization=Personalization(
        k=3, every=5, warmup=20)), problem=built.problem)
    # equal bits: censor_v=0 -> every agent transmits every iteration
    np.testing.assert_array_equal(np.asarray(cons.history["bits"]),
                                  np.asarray(pers.history["bits"]))

    def per_agent_mse(theta):
        pred = jnp.einsum("nsd,nd->ns", built.feats_test, theta)
        return float(jnp.mean((built.labels_test - pred) ** 2))

    mse_cons = per_agent_mse(jnp.broadcast_to(
        jnp.mean(cons.theta, axis=0), cons.theta.shape))
    mse_pers = per_agent_mse(pers.theta)
    assert mse_pers < mse_cons, (mse_pers, mse_cons)
    assert float(P.graph_recovery(pers.learned_adjacency,
                                  built.clusters)) > 0.6


# ---------------------------------------------------------------------------
# Validation surface
# ---------------------------------------------------------------------------

def test_admission_errors():
    with pytest.raises(ValueError, match="fused"):
        fit(BASE.replace(personalization=PZ, backend="fused"))
    with pytest.raises(ValueError, match="Cholesky"):
        fit(BASE.replace(personalization=PZ, primal="cholesky"))
    from repro.api import TopologySchedule
    with pytest.raises(ValueError, match="personalization"):
        BASE.replace(personalization=PZ,
                     topology=TopologySchedule.circulant_cycle(
                         KRR.num_agents, [(1,)]))
    with pytest.raises(ValueError, match="solver"):
        fit(BASE.replace(algorithm="cta", comm=None, personalization=PZ))
    from repro.api import ChurnSchedule
    with pytest.raises(ValueError, match="churn"):
        BASE.replace(exec="gossip", personalization=PZ,
                     churn=ChurnSchedule(leave=((5, 1),)))


def test_personalization_config_validation():
    with pytest.raises(ValueError):
        Personalization(k=0)
    with pytest.raises(ValueError):
        Personalization(affinity="euclid")
    with pytest.raises(ValueError):
        Personalization(every=0)
    with pytest.raises(ValueError):
        Personalization(warmup=-1)
