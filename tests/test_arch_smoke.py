"""Assigned-architecture smoke tests (deliverable f): for every arch, a
REDUCED variant of the same family runs one forward + one train step + one
decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.optim.optimizers import OptConfig, apply_updates, init_opt_state, \
    opt_update

ARCHS = list_archs()


def _batch(cfg, B=2, S=32):
    if cfg.is_encdec:
        return {"encoder_embeds": jnp.ones((B, S // 2, cfg.d_model)) * 0.1,
                "tokens": jnp.zeros((B, S // 2), jnp.int32),
                "labels": jnp.ones((B, S // 2), jnp.int32)}
    if cfg.prefix_len:
        return {"prefix_embeds": jnp.ones((B, cfg.prefix_len, cfg.d_model))
                * 0.1,
                "tokens": jnp.zeros((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()

    (loss, _), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
        params, cfg, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0

    opt_cfg = OptConfig(lr=1e-3)
    opt = init_opt_state(opt_cfg, params)
    updates, _ = opt_update(opt_cfg, grads, opt, params)
    params2 = apply_updates(params, updates)
    loss2, _ = M.loss_fn(params2, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    state = M.init_serve_state(cfg, B, cache_len=16,
                               enc_len=8 if cfg.is_encdec else 0)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = M.decode_step(params, cfg, token, state,
                                   jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # state advanced (same structure)
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) config carries the exact assigned numbers."""
    cfg = get_config(arch)
    expected = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "mamba2-2.7b": (64, 2560, None, None, 0, 50280),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
    }[arch]
    L, d, H, KV, ff, V = expected
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab_size == V
    if H is not None:
        assert cfg.num_heads == H and cfg.num_kv_heads == KV
    assert cfg.source  # every config cites its source


def test_arch_specials():
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("mixtral-8x7b").sliding_window == 4096
    assert get_config("mixtral-8x7b").num_experts == 8
    assert get_config("deepseek-v2-lite-16b").kv_lora_rank == 512
    assert get_config("deepseek-v2-lite-16b").num_shared_experts == 2
    assert get_config("zamba2-2.7b").shared_attn_every == 6
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("minicpm3-4b").attn_kind == "mla"
    assert get_config("seamless-m4t-medium").encoder_layers == 12
    assert get_config("internvl2-1b").prefix_len > 0
