"""Mamba2 SSD: chunked algorithm vs naive recurrence; decode consistency;
chunk-size invariance (the state-space-duality property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import ssm
from repro.models.common import ModelConfig


def _naive_ssd(x, dt, A, Bm, Cm):
    """Token-by-token linear recurrence oracle.
    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B_, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)                      # (B,H)
        inc = jnp.einsum("bn,bhp,bh->bhpn", Bm[:, t], x[:, t], dt[:, t])
        state = state * dA[:, :, None, None] + inc
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], state))
    return jnp.stack(ys, axis=1), state


def _inputs(B=2, S=24, H=3, P=4, N=5, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, S, N)) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_chunked_matches_naive(chunk):
    x, dt, A, Bm, Cm = _inputs()
    y_ref, st_ref = _naive_ssd(x, dt, A, Bm, Cm)
    y, st_out = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_out), np.asarray(st_ref),
                               atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(st.sampled_from([3, 5, 6, 12]), st.sampled_from([2, 4, 7]))
def test_chunk_size_invariance(chunk_a, chunk_b):
    """SSD output must not depend on the chunking (duality property)."""
    x, dt, A, Bm, Cm = _inputs(S=12, seed=3)
    ya, _ = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk_a)
    yb, _ = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-4)


def _ssm_cfg():
    return ModelConfig(name="t", arch_type="ssm", num_layers=1, d_model=32,
                       num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=64,
                       attn_kind="none", ssm_state=8, ssm_head_dim=8,
                       ssm_expand=2, ssm_chunk=8)


def test_ssm_decode_matches_forward():
    cfg = _ssm_cfg()
    params = ssm.init_ssm_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.3
    full = ssm.ssm_forward(params, cfg, x)

    cache = ssm.SSMCache(
        conv_x=jnp.zeros((B, cfg.ssm_conv_width - 1, cfg.d_inner)),
        conv_bc=jnp.zeros((B, cfg.ssm_conv_width - 1, 2 * cfg.ssm_state)),
        state=jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state)))
    outs = []
    for t in range(S):
        o, cache = ssm.ssm_decode(params, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_forward_returns_cache_consistent_with_decode():
    """Prefill-then-decode: cache from forward continues the sequence."""
    cfg = _ssm_cfg()
    params = ssm.init_ssm_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S + 1, cfg.d_model)) * 0.3
    full = ssm.ssm_forward(params, cfg, x)
    _, cache = ssm.ssm_forward(params, cfg, x[:, :S], return_cache=True)
    o, _ = ssm.ssm_decode(params, cfg, x[:, S:S + 1], cache)
    np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, S:S + 1]),
                               atol=2e-4)
