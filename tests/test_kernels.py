"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps. The wrappers'
`interpret=None` default resolves to interpret mode on this CPU suite
(compiled on TPU/GPU — see repro.kernels.runtime.resolve_interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import draw_rff, featurize
from repro.kernels.coke_update.coke_update import coke_fused_update
from repro.kernels.coke_update.ops import coke_update_pytree
from repro.kernels.coke_update.ref import coke_update_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import gqa_flash
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rff.ops import featurize_fused
from repro.kernels.rff.ref import rff_ref


# --------------------------- rff ------------------------------------------

@pytest.mark.parametrize("T,d,L", [(64, 5, 32), (300, 77, 100),
                                   (128, 96, 200), (33, 13, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rff_kernel_shapes_dtypes(T, d, L, dtype):
    p = draw_rff(jax.random.PRNGKey(0), d, L, 1.0)
    p = type(p)(p.omega.astype(dtype), p.bias.astype(dtype), p.mapping)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d), dtype)
    out = featurize_fused(p, x)
    ref = rff_ref(x, p.omega, p.bias)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_rff_kernel_matches_core_featurize():
    p = draw_rff(jax.random.PRNGKey(2), 5, 64, 2.0)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 50, 5))
    out = featurize_fused(p, x)
    core = featurize(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(core), atol=1e-5)


# --------------------------- flash attention ------------------------------

@pytest.mark.parametrize("Sq,Sk,blocks", [(128, 128, 64), (100, 100, 32),
                                          (257, 257, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32),
                                           (False, 0)])
def test_flash_attention_sweep(Sq, Sk, blocks, causal, window):
    B, H, Dh = 2, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh))
    k = jax.random.normal(ks[1], (B, H, Sk, Dh))
    v = jax.random.normal(ks[2], (B, H, Sk, Dh))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=blocks, block_k=blocks)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, H, S, Dh = 1, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, S, Dh), dtype)
    k = jax.random.normal(ks[1], (B, H, S, Dh), dtype)
    v = jax.random.normal(ks[2], (B, H, S, Dh), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32)
    ref = attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_gqa_flash_grouped_heads():
    q = jax.random.normal(jax.random.PRNGKey(6), (2, 96, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(7), (2, 96, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(8), (2, 96, 2, 16))
    out = gqa_flash(q, k, v, block_q=32, block_k=32)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), 4, 1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), 4, 1)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kr, vr).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------- coke fused update ----------------------------

@pytest.mark.parametrize("N,D", [(4, 100), (8, 1000), (2, 513), (1, 4096)])
@pytest.mark.parametrize("rho", [0.01, 1.0])
def test_coke_update_sweep(N, D, rho):
    args = [jax.random.normal(k, (N, D))
            for k in jax.random.split(jax.random.PRNGKey(9), 6)]
    g_k, xi_k = coke_fused_update(*args, rho=rho)
    g_r, xi_r = coke_update_ref(*args, rho=rho)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(xi_k), np.asarray(xi_r),
                               rtol=1e-4)


def test_coke_update_pytree_wrapper():
    N = 4
    mk = lambda k, shape: jax.random.normal(k, (N, *shape))
    keys = jax.random.split(jax.random.PRNGKey(10), 30).reshape(6, 5, 2)
    trees = []
    for i in range(6):
        trees.append({"a": mk(keys[i, 0], (3, 7)), "b": mk(keys[i, 1], (11,)),
                      "c": {"d": mk(keys[i, 2], (2, 2, 2))}})
    gaug, xi = coke_update_pytree(*trees, rho=0.1)
    assert jax.tree.structure(gaug) == jax.tree.structure(trees[0])
    # oracle on the flattened view
    flat = [jnp.concatenate([l.reshape(N, -1) for l in jax.tree.leaves(t)], 1)
            for t in trees]
    g_r, xi_sq = coke_update_ref(*flat, rho=0.1)
    flat_gaug = jnp.concatenate(
        [l.reshape(N, -1) for l in jax.tree.leaves(gaug)], 1)
    np.testing.assert_allclose(np.asarray(flat_gaug), np.asarray(g_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(xi), np.sqrt(np.asarray(xi_sq)),
                               rtol=1e-4)
