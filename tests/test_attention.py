"""Attention: blockwise == naive; decode-vs-forward consistency; MLA
absorbed decode == expanded forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import ModelConfig


def _naive(q, k, v, positions_q, positions_k, causal=True, window=0):
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / jnp.sqrt(float(Dh))
    valid = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        valid &= positions_k[None, :] <= positions_q[:, None]
    if window:
        valid &= positions_k[None, :] > positions_q[:, None] - window
    valid &= positions_q[:, None] >= 0
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                           (False, 0)])
@pytest.mark.parametrize("Sq,block", [(64, 16), (50, 16), (33, 64)])
def test_blockwise_matches_naive(causal, window, Sq, block):
    key = jax.random.PRNGKey(0)
    B, H, KV, Dh = 2, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh))
    k = jax.random.normal(ks[1], (B, Sq, KV, Dh))
    v = jax.random.normal(ks[2], (B, Sq, KV, Dh))
    pos = jnp.arange(Sq)
    out_b = A.blockwise_attention(q, k, v, pos, pos, causal=causal,
                                  window=window, block_q=block,
                                  block_k=block)
    out_n = _naive(q, k, v, pos, pos, causal, window)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_n),
                               atol=2e-5)


def _gqa_cfg(**kw):
    base = dict(name="t", arch_type="dense", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                vocab_size=64, attn_block_q=16, attn_block_k=16)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("qk_norm", [False, True])
@pytest.mark.parametrize("window", [0, 8])
def test_gqa_decode_matches_forward(qk_norm, window):
    """Token-by-token decode reproduces the full forward output."""
    cfg = _gqa_cfg(qk_norm=qk_norm, sliding_window=window)
    params = A.init_gqa_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    pos = jnp.arange(S)
    full = A.gqa_forward(params, cfg, x, pos)

    C = window or S
    cache = A.KVCache(
        k=jnp.zeros((B, C, cfg.num_kv_heads, cfg.resolved_head_dim)),
        v=jnp.zeros((B, C, cfg.num_kv_heads, cfg.resolved_head_dim)),
        slot_positions=jnp.full((C,), -1, jnp.int32))
    outs = []
    for t in range(S):
        o, cache = A.gqa_decode(params, cfg, x[:, t:t + 1], cache,
                                jnp.asarray(t))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-5)


def test_gqa_prefill_cache_then_decode_matches_forward():
    cfg = _gqa_cfg()
    params = A.init_gqa_params(cfg, jax.random.PRNGKey(3))
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S + 1, cfg.d_model))
    pos = jnp.arange(S + 1)
    full = A.gqa_forward(params, cfg, x, pos)

    cache = A.gqa_prefill_cache(params, cfg, x[:, :S], pos[:S], cache_len=16)
    o, _ = A.gqa_decode(params, cfg, x[:, S:S + 1], cache, jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, S:S + 1]),
                               atol=3e-5)


@pytest.mark.parametrize("q_lora", [0, 24])
def test_mla_absorbed_decode_matches_forward(q_lora):
    """The latent-space (absorbed) decode equals the expanded forward."""
    cfg = _gqa_cfg(attn_kind="mla", kv_lora_rank=16, q_lora_rank=q_lora,
                   qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    params = A.init_mla_params(cfg, jax.random.PRNGKey(5))
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model))
    pos = jnp.arange(S)
    full = A.mla_forward(params, cfg, x, pos)

    cache = A.MLACache(
        ckv=jnp.zeros((B, S, cfg.kv_lora_rank)),
        krope=jnp.zeros((B, S, cfg.qk_rope_dim)),
        slot_positions=jnp.full((S,), -1, jnp.int32))
    outs = []
    for t in range(S):
        o, cache = A.mla_decode(params, cfg, x[:, t:t + 1], cache,
                                jnp.asarray(t))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-5)


def test_rolling_cache_evicts_old_positions():
    """SWA rolling cache: positions older than the window are overwritten
    and masked out."""
    cfg = _gqa_cfg(sliding_window=4)
    params = A.init_gqa_params(cfg, jax.random.PRNGKey(7))
    B, S = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(8), (B, S, cfg.d_model))
    C = 4
    cache = A.KVCache(
        k=jnp.zeros((B, C, cfg.num_kv_heads, cfg.resolved_head_dim)),
        v=jnp.zeros((B, C, cfg.num_kv_heads, cfg.resolved_head_dim)),
        slot_positions=jnp.full((C,), -1, jnp.int32))
    for t in range(S):
        o, cache = A.gqa_decode(params, cfg, x[:, t:t + 1], cache,
                                jnp.asarray(t))
    # all slots hold positions within the last window
    slots = np.asarray(cache.slot_positions)
    assert slots.min() >= S - C


@pytest.mark.parametrize("kind", ["gqa", "mla"])
def test_tp_head_padding_is_exact(kind):
    """tp_head_pad physically pads Q heads to a shardable multiple with
    zero-initialized wo rows — outputs must equal the unpadded model
    exactly (the §Perf D lever for 14/40-head archs on a 16-way mesh)."""
    base = dict(name="t", arch_type="dense", num_layers=1, d_model=64,
                num_heads=5, num_kv_heads=1, head_dim=16, d_ff=64,
                vocab_size=64, attn_block_q=16, attn_block_k=16)
    if kind == "mla":
        base.update(attn_kind="mla", kv_lora_rank=16, qk_nope_dim=8,
                    qk_rope_dim=4, v_head_dim=8)
    cfg0 = ModelConfig(**base)
    cfg1 = ModelConfig(**base, tp_head_pad=8)
    assert cfg1.padded_heads == 8 and cfg0.padded_heads == 5

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))
    pos = jnp.arange(12)
    if kind == "gqa":
        p1 = A.init_gqa_params(cfg1, key)
        p0 = dict(p1, wq=p1["wq"][:, :5], wo=p1["wo"][:5])
        o0 = A.gqa_forward(p0, cfg0, x, pos)
        o1 = A.gqa_forward(p1, cfg1, x, pos)
    else:
        p1 = A.init_mla_params(cfg1, key)
        p0 = dict(p1, wq=p1["wq"][:, :5], wkv_b=p1["wkv_b"][:, :5],
                  wo=p1["wo"][:5])
        o0 = A.mla_forward(p0, cfg0, x, pos)
        o1 = A.mla_forward(p1, cfg1, x, pos)
    np.testing.assert_array_equal(np.asarray(o0), np.asarray(o1))
