"""Sharding rules: every spec must be valid (divisible) for every arch on
the production meshes — the invariant the dry-run relies on. Runs on a
1-device host (specs are pure metadata; no allocation)."""
import math

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs
from repro.configs.registry import list_archs
from repro.distributed import sharding as shd
from repro.models import model as M


class FakeMesh:
    """Metadata-only mesh stand-in (axis sizes + names)."""

    def __init__(self, shape_by_axis):
        self.shape = shape_by_axis
        self.axis_names = tuple(shape_by_axis)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_spec_divides(spec: P, shape, mesh, where: str):
    assert len(spec) <= len(shape), f"{where}: spec longer than shape"
    for dim, axis in zip(shape, spec):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        extent = math.prod(mesh.shape[a] for a in axes)
        assert dim % extent == 0, \
            f"{where}: dim {dim} not divisible by {axes}={extent}"


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    shapes = M.param_shapes(cfg)
    specs = shd.param_specs(cfg, shapes, mesh)
    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_shapes, flat_specs):
        _check_spec_divides(spec, leaf.shape, mesh,
                            f"{arch}:{jax.tree_util.keystr(path)}")


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_decode_and_batch_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    rcfg, kind, specs = input_specs(cfg, shape_name)
    if rcfg is None:
        pytest.skip("pair skipped by design")
    for mesh in (SINGLE, MULTI):
        in_sp = shd.step_in_specs(rcfg, kind, specs, mesh)
        tree = specs if kind != "decode" else specs
        flat_shapes = jax.tree_util.tree_flatten_with_path(tree)[0]
        flat_specs = jax.tree.leaves(
            in_sp, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_shapes, flat_specs):
            _check_spec_divides(spec, leaf.shape, mesh,
                                f"{arch}:{shape_name}:"
                                f"{jax.tree_util.keystr(path)}")


def test_vocab_padding_divisible_by_model_axis():
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_tensor_parallel_falls_back_to_replication():
    """internvl2 has 14 heads (not divisible by 16): wq must replicate the
    head dim rather than shard it."""
    cfg = get_config("internvl2-1b")
    shapes = M.param_shapes(cfg)
    specs = shd.param_specs(cfg, shapes, SINGLE)
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert wq_spec[1 + 1] is None  # (layer, d, H, Dh): H replicated


def test_kv_cache_sequence_parallel_fallback():
    """granite decode: 8 KV heads < 16 model shards -> cache length dim is
    sharded over model instead (sequence-parallel KV)."""
    cfg = get_config("granite-3-8b")
    rcfg, kind, specs = input_specs(cfg, "decode_32k")
    state_specs = shd.decode_state_specs(rcfg, specs["state"], SINGLE)
    k_spec = state_specs["layers"].k
    assert k_spec[3] is None      # KV heads replicated
    assert k_spec[2] == "model"   # cache length sharded


def test_long500k_window_variant_and_skips():
    from repro.configs.shapes import long_context_mode
    assert long_context_mode(get_config("mamba2-2.7b")) == "native"
    assert long_context_mode(get_config("zamba2-2.7b")) == "native"
    assert long_context_mode(get_config("mixtral-8x7b")) == "native"
    assert long_context_mode(get_config("seamless-m4t-medium")) == "skip"
    assert long_context_mode(get_config("llama3-405b")) == "window-variant"
    rcfg, _, _ = input_specs(get_config("llama3-405b"), "long_500k")
    assert rcfg.sliding_window == 4096
    rcfg, kind, specs = input_specs(get_config("seamless-m4t-medium"),
                                    "long_500k")
    assert rcfg is None
