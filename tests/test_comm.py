"""The composable communication layer: stage semantics, bit accounting,
identity-chain bit-parity with plain COKE, time-varying topologies, and
the (v, mu, bits) sweep axis with its deterministic operating-point rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_fit_parity, assert_results_match

from repro.api import (Censor, Chain, Drop, FitConfig, KRRConfig, Quantize,
                       TopologySchedule, build_problem, fit, sweep)
from repro.core import comm
from repro.core.graph import ring

KRR = KRRConfig(num_agents=6, samples_per_agent=50, num_features=16,
                lam=1e-2, rho=0.5, seed=0)
BASE = FitConfig(krr=KRR, algorithm="coke", censor_v=0.5, censor_mu=0.97,
                 num_iters=60)


@pytest.fixture(scope="module")
def built():
    return build_problem(BASE)


# ---------------------------------------------------------------------------
# Stage semantics
# ---------------------------------------------------------------------------

def test_as_chain_normalizes_spellings():
    from repro.core.censor import CensorSchedule
    assert comm.as_chain(None).stages == ()
    assert comm.as_chain(Censor(1.0, 0.9)).stages == (Censor(1.0, 0.9),)
    assert comm.as_chain([Censor(1.0, 0.9), Drop(0.1)]).stages == (
        Censor(1.0, 0.9), Drop(0.1))
    assert comm.as_chain(CensorSchedule(0.3, 0.9)).stages == (
        Censor(0.3, 0.9),)
    with pytest.raises(TypeError, match="policy"):
        comm.as_chain("censor")


def test_empty_chain_broadcasts_full_precision():
    theta = jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4)
    hat = jnp.zeros((3, 4))
    chain = Chain(())
    hat2, send, state = chain.apply(theta, hat, jnp.int32(1),
                                    chain.init_state(3))
    np.testing.assert_array_equal(np.asarray(hat2), np.asarray(theta))
    assert bool(jnp.all(send))
    # 4 float32 coordinates = 128 bits per agent
    np.testing.assert_array_equal(np.asarray(state.bits), [128, 128, 128])


def test_censored_agents_pay_nothing():
    theta = jnp.zeros((4, 8))
    theta = theta.at[0].set(10.0)   # only agent 0 moved
    hat = jnp.zeros((4, 8))
    chain = Chain((Censor(v=1.0, mu=1.0),))
    hat2, send, state = chain.apply(theta, hat, jnp.int32(1),
                                    chain.init_state(4))
    np.testing.assert_array_equal(np.asarray(send), [True] + [False] * 3)
    np.testing.assert_array_equal(np.asarray(state.bits),
                                  [8 * 32, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(hat2[1:]),
                                  np.asarray(hat[1:]))


def test_quantize_infinite_bits_is_exact_identity():
    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (5, 16))
    hat = jax.random.normal(jax.random.fold_in(key, 1), (5, 16))
    chain = Chain((Quantize(bits=float("inf")),))
    hat2, _, state = chain.apply(theta, hat, jnp.int32(3),
                                 chain.init_state(5))
    np.testing.assert_array_equal(np.asarray(hat2), np.asarray(theta))
    np.testing.assert_array_equal(np.asarray(state.bits),
                                  np.full(5, 16 * 32))


def test_quantize_is_unbiased_and_bounded():
    key = jax.random.PRNGKey(0)
    theta = jax.random.normal(key, (4, 64))
    hat = jnp.zeros((4, 64))
    stage = Quantize(bits=4.0)
    outs = []
    for k in range(200):
        msg = comm.Msg(theta, hat, jnp.ones((4,), bool),
                       jnp.ones((4,), bool),
                       jnp.asarray(32.0), jnp.zeros(()))
        out, _ = stage.transform(msg, (), jnp.int32(k + 1))
        outs.append(np.asarray(out.payload))
    outs = np.stack(outs)
    scale = np.abs(np.asarray(theta)).max(-1, keepdims=True)
    step = scale / (2.0 ** 3 - 1)          # one quantization level
    # stochastic rounding: each draw within one level of the true value
    assert np.max(np.abs(outs - np.asarray(theta)[None])) <= step.max() + 1e-6
    # and unbiased: the mean over draws converges to the true value
    assert np.max(np.abs(outs.mean(0) - np.asarray(theta))) < 0.3 * step.max()


def test_quantize_accounts_payload_plus_scale_overhead():
    theta = jnp.ones((2, 16))
    hat = jnp.zeros((2, 16))
    chain = Chain((Quantize(bits=4.0),))
    _, _, state = chain.apply(theta, hat, jnp.int32(1), chain.init_state(2))
    np.testing.assert_array_equal(np.asarray(state.bits),
                                  np.full(2, 16 * 4 + 32))


def test_drop_pays_but_does_not_deliver():
    theta = jnp.ones((400, 4))
    hat = jnp.zeros((400, 4))
    chain = Chain((Drop(p=0.5),))
    hat2, send, state = chain.apply(theta, hat, jnp.int32(1),
                                    chain.init_state(400))
    delivered = np.all(np.asarray(hat2) == 1.0, axis=-1)
    # every agent transmitted (and paid)...
    assert bool(jnp.all(send))
    np.testing.assert_array_equal(np.asarray(state.bits),
                                  np.full(400, 4 * 32))
    # ...but roughly half the broadcasts were lost (stale value kept)
    assert 0.3 < delivered.mean() < 0.7
    np.testing.assert_array_equal(np.asarray(hat2)[~delivered],
                                  np.asarray(hat)[~delivered])


def test_sweep_cells_draw_independent_drop_randomness():
    """Regression (comm RNG correlation): distinct vmapped policy cells
    must draw INDEPENDENT link-drop randomness. Under the old static-seed
    derivation every cell shared one uniform draw, so the p=0.6 cell's
    delivered set was always a subset of the p=0.3 cell's."""
    from repro.api.sweep import _stack_policies

    theta = jnp.ones((256, 4))
    hat = jnp.zeros((256, 4))
    stacked = _stack_policies([Chain([Drop(p=0.3)]), Chain([Drop(p=0.6)])])

    def delivered(chain):
        out, _, _ = chain.apply(theta, hat, jnp.int32(1),
                                chain.init_state(256))
        return jnp.all(out == 1.0, axis=-1)

    a, b = np.asarray(jax.vmap(delivered)(stacked))
    # independent draws: each cell delivers some agents the other dropped
    assert (a & ~b).sum() > 0
    assert (~a & b).sum() > 0  # impossible under the correlated legacy draw


def test_sweep_cells_draw_independent_quantize_randomness():
    """Cells differing only in the CENSOR threshold still get their own
    rounding stream (the whole chain's parameters key the stream), while
    byte-identical cells stay byte-identical — the deterministic tie-break
    contract."""
    from repro.api.sweep import _stack_policies

    key = jax.random.PRNGKey(3)
    theta = jax.random.normal(key, (8, 64))
    hat = jnp.zeros((8, 64))

    def payload(chain):
        out, _, _ = chain.apply(theta, hat, jnp.int32(1),
                                chain.init_state(8))
        return out

    cells = [Chain([Censor(0.5, 0.97), Quantize(4.0)]),
             Chain([Censor(0.6, 0.97), Quantize(4.0)]),
             Chain([Censor(0.5, 0.97), Quantize(4.0)])]
    p0, p1, p2 = np.asarray(jax.vmap(payload)(_stack_policies(cells)))
    assert not np.array_equal(p0, p1)       # distinct cells: fresh noise
    np.testing.assert_array_equal(p0, p2)   # identical cells: identical


def test_select_without_bits_history_falls_back_to_comms(built):
    """Satellite: a SweepResult lacking a `bits` trajectory must rank on
    (comms, index) EXPLICITLY — not silently pretend transmission counts
    are bit totals (a ~D*32x unit mismatch)."""
    import dataclasses

    grid = ((0.5, 0.97), (0.05, 0.999), (0.5, 0.97))
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    no_bits = dataclasses.replace(
        sw, history={k: v for k, v in sw.history.items() if k != "bits"})
    x, y = built.x_test, built.y_test
    idx, _ = no_bits.select(x, y, max_mse_gap=10.0,
                            rff_params=built.rff_params)
    ev = no_bits.evaluate(x, y, rff_params=built.rff_params)
    assert "bits" not in ev
    comms = np.asarray(ev["comms"])
    # fewest transmissions wins; duplicate cells resolve to the lowest index
    assert comms[idx] == comms.min()
    assert idx == int(np.flatnonzero(comms == comms.min())[0])


def test_drop_is_deterministic_in_k_and_seed():
    theta = jnp.ones((64, 4))
    hat = jnp.zeros((64, 4))
    def run(seed, k):
        chain = Chain((Drop(p=0.5, seed=seed),))
        out, _, _ = chain.apply(theta, hat, jnp.int32(k),
                                chain.init_state(64))
        return np.asarray(out)
    np.testing.assert_array_equal(run(1, 7), run(1, 7))
    assert not np.array_equal(run(1, 7), run(1, 8))
    assert not np.array_equal(run(1, 7), run(2, 7))


# ---------------------------------------------------------------------------
# fit() integration: identity parity, deprecation shims, bits metric
# ---------------------------------------------------------------------------

def test_identity_chain_bit_identical_to_plain_coke(built):
    """Acceptance: Chain([Censor(v, mu), Quantize(bits=inf), Drop(p=0)])
    reproduces today's COKE trajectory bit-for-bit."""
    plain = fit(BASE, problem=built.problem)
    ident = fit(BASE.replace(
        censor_v=None, censor_mu=None,
        comm=Chain([Censor(0.5, 0.97), Quantize(bits=float("inf")),
                    Drop(p=0.0)])), problem=built.problem)
    assert_results_match(plain, ident, exact="*", err="identity-chain")


def test_identity_chain_bit_identical_on_spmd_and_fused(ring6):
    """Acceptance, distributed legs: the identity extension reproduces the
    plain-COKE trajectory bit-for-bit on the ring runtime and the fused
    Pallas path too."""
    ident = Chain([Censor(0.3, 0.97), Quantize(bits=float("inf")),
                   Drop(p=0.0)])
    for backend in ("spmd", "fused"):
        plain = fit(RING6.replace(backend=backend), problem=ring6.problem)
        chained = fit(RING6.replace(backend=backend, censor_v=None,
                                    censor_mu=None, comm=ident),
                      problem=ring6.problem)
        assert_results_match(plain, chained, exact="*", err=backend)


def test_legacy_censor_knobs_map_onto_chain(built):
    """Migration shim: censor_v/censor_mu IS comm=Chain([Censor(v, mu)])."""
    legacy = fit(BASE, problem=built.problem)
    chained = fit(BASE.replace(censor_v=None, censor_mu=None,
                               comm=Chain([Censor(0.5, 0.97)])),
                  problem=built.problem)
    np.testing.assert_array_equal(np.asarray(legacy.theta),
                                  np.asarray(chained.theta))
    np.testing.assert_array_equal(np.asarray(legacy.bits),
                                  np.asarray(chained.bits))
    assert legacy.config.resolved_comm == chained.config.resolved_comm


def test_comm_conflicts_with_legacy_knobs():
    with pytest.raises(ValueError, match="censor_v"):
        FitConfig(comm=Chain([Censor(0.5, 0.97)]), censor_v=0.5)
    with pytest.raises(TypeError, match="policy"):
        FitConfig(comm="quantize")


def test_comm_unaware_solvers_reject_policies(built):
    for algorithm in ("cta", "ridge_oracle"):
        with pytest.raises(ValueError, match="comm"):
            fit(BASE.replace(algorithm=algorithm,
                             censor_v=None, censor_mu=None,
                             comm=Chain([Drop(p=0.5)])),
                problem=built.problem)


def test_bits_metric_consistent_with_comms(built):
    r = fit(BASE, problem=built.problem)
    # censor-only full-precision policy: bits == comms * D * 32 exactly
    np.testing.assert_array_equal(
        np.asarray(r.bits),
        np.asarray(r.comms) * KRR.num_features * 32)
    q = fit(BASE.replace(censor_v=None, censor_mu=None,
                         comm=Chain([Censor(0.5, 0.97), Quantize(bits=4)])),
            problem=built.problem)
    # 4-bit payloads + one float32 scale per message
    assert int(q.bits[-1]) == int(q.comms[-1]) * (KRR.num_features * 4 + 32)


def test_quantized_coke_converges_under_drops(built):
    r = fit(BASE.replace(censor_v=None, censor_mu=None, num_iters=150,
                         comm=Chain([Censor(0.5, 0.97), Quantize(bits=6),
                                     Drop(p=0.1)])),
            problem=built.problem)
    assert float(r.train_mse[-1]) < 2.5 * float(
        fit(BASE.replace(num_iters=150),
            problem=built.problem).train_mse[-1])


def test_dkla_applies_compression_but_not_censoring(built):
    r = fit(BASE.replace(algorithm="dkla", censor_v=None, censor_mu=None,
                         comm=Chain([Censor(5.0, 0.999), Quantize(bits=8)]),
                         num_iters=30), problem=built.problem)
    # censor thresholds stripped -> every agent transmits every iteration
    assert int(r.comms[-1]) == 30 * KRR.num_agents
    # ...but the quantizer still applied: 8-bit payloads + scale overhead
    assert int(r.bits[-1]) == 30 * KRR.num_agents * (
        KRR.num_features * 8 + 32)


# ---------------------------------------------------------------------------
# Time-varying topology
# ---------------------------------------------------------------------------

RING6 = FitConfig(
    krr=KRRConfig(num_agents=6, samples_per_agent=40, num_features=32,
                  lam=1e-2, rho=0.1, seed=0),
    graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
    num_iters=60, primal="gradient", inner_steps=1, inner_lr=0.05)


@pytest.fixture(scope="module")
def ring6():
    return build_problem(RING6)


def test_topology_schedule_cycles_graphs():
    topo = TopologySchedule.circulant_cycle(6, [(1,), (1, 2)])
    assert topo.num_graphs == 2 and topo.num_agents == 6
    assert int(topo.index(1)) == 0 and int(topo.index(2)) == 1
    assert int(topo.index(3)) == 0
    np.testing.assert_array_equal(np.asarray(topo.at(3)),
                                  np.asarray(topo.adjacencies[0]))


def test_single_graph_schedule_matches_static(ring6):
    static = fit(RING6, problem=ring6.problem)
    sched = fit(RING6.replace(
        topology=TopologySchedule.circulant_cycle(6, [(1,)])),
        problem=ring6.problem)
    np.testing.assert_allclose(np.asarray(static.theta),
                               np.asarray(sched.theta), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(static.comms),
                                  np.asarray(sched.comms))


def test_time_varying_topology_simulator_spmd_parity(ring6):
    cfg = RING6.replace(
        topology=TopologySchedule.circulant_cycle(6, [(1,), (1, 2)]))
    assert_fit_parity(cfg, ("simulator", "spmd"), problem=ring6.problem,
                      exact=("comms", "bits"), theta_atol=1e-5)


def test_time_varying_topology_closed_form_primal(ring6):
    """The per-graph Cholesky stack: denser intermittent connectivity must
    still converge (and not crash the prefactored path)."""
    r = fit(RING6.replace(
        primal="auto", inner_steps=50,
        topology=TopologySchedule.circulant_cycle(6, [(1,), (1, 2)])),
        problem=ring6.problem)
    assert float(r.train_mse[-1]) < float(r.train_mse[0])


def test_spmd_topology_requires_offsets_and_rejects_degenerate(ring6):
    no_off = TopologySchedule.from_graphs([ring(6)])
    with pytest.raises(ValueError, match="offsets"):
        fit(RING6.replace(backend="spmd", topology=no_off),
            problem=ring6.problem)
    with pytest.raises(ValueError, match="degenerate"):
        fit(RING6.replace(backend="spmd",
                          topology=TopologySchedule.circulant_cycle(
                              6, [(1, 3)])),
            problem=ring6.problem)


def test_fused_backend_rejects_time_varying_topology(ring6):
    with pytest.raises(ValueError, match="static"):
        fit(RING6.replace(backend="fused",
                          topology=TopologySchedule.circulant_cycle(
                              6, [(1,), (1, 2)])),
            problem=ring6.problem)


def test_topology_unaware_solvers_reject_schedules(built):
    topo = TopologySchedule.circulant_cycle(6, [(1,)])
    with pytest.raises(ValueError, match="topology"):
        fit(BASE.replace(algorithm="cta", topology=topo),
            problem=built.problem)


# ---------------------------------------------------------------------------
# sweep over (v, mu, bits) and deterministic select
# ---------------------------------------------------------------------------

def test_sweep_vmu_bits_grid_matches_individual_fits(built):
    """(v, mu, bits) tuple cells: send decisions and bit accounting agree
    exactly between the vmapped grid and per-cell fits. (Quantized *values*
    are compared in the deterministic-rounding test below — vmapped float
    LSBs can flip a stochastic rounding draw.)"""
    grid = ((0.5, 0.97, 4.0), (0.5, 0.97, float("inf")),
            (0.1, 0.99, 4.0))
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    assert len(sw) == 3
    for gi, (v, mu, bits) in enumerate(grid):
        r = fit(BASE.replace(censor_v=None, censor_mu=None,
                             comm=Chain([Censor(v, mu), Quantize(bits)])),
                problem=built.problem)
        np.testing.assert_array_equal(
            np.asarray(sw.history["comms"][gi]), np.asarray(r.comms))
        np.testing.assert_array_equal(
            np.asarray(sw.history["bits"][gi]), np.asarray(r.bits))


def test_sweep_policy_cells_match_individual_fits_deterministic(built):
    """Explicit policy cells with deterministic rounding: the vmapped grid
    reproduces each individual fit's trajectory."""
    grid = [Chain([Censor(v, mu), Quantize(b, stochastic=False)])
            for (v, mu, b) in ((0.5, 0.97, 4.0), (0.5, 0.97, float("inf")),
                               (0.1, 0.99, 6.0))]
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    for gi, chain in enumerate(grid):
        r = fit(BASE.replace(censor_v=None, censor_mu=None, comm=chain),
                problem=built.problem)
        np.testing.assert_array_equal(
            np.asarray(sw.history["comms"][gi]), np.asarray(r.comms))
        np.testing.assert_array_equal(
            np.asarray(sw.history["bits"][gi]), np.asarray(r.bits))
        # vmapped Cholesky solves differ at float32 lsb; the quantizer's
        # level spacing amplifies that slightly beyond the censor-only case
        np.testing.assert_allclose(np.asarray(sw.thetas[gi]),
                                   np.asarray(r.theta), atol=1e-4)


def test_sweep_rejects_mixed_policy_structures(built):
    with pytest.raises(ValueError, match="structure"):
        sweep(BASE, ((0.5, 0.97), (0.5, 0.97, 4.0)), problem=built.problem)


def test_sweep_select_tie_breaking_deterministic(built):
    """Satellite: the operating-point rule under the bits axis. Duplicate
    cells tie on (MSE, bits, comms); the rule must resolve to the LOWEST
    index, stably across repeated evaluations and grid duplications."""
    grid = ((0.5, 0.97, float("inf")), (0.5, 0.97, 4.0),
            (0.5, 0.97, 4.0), (0.5, 0.97, 4.0))
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    x, y = built.x_test, built.y_test
    picks = [sw.select(x, y, max_mse_gap=10.0,
                       rff_params=built.rff_params)[0] for _ in range(3)]
    assert picks == [picks[0]] * 3
    # with a huge allowed gap every cell qualifies; the three identical
    # 4-bit cells tie on bits and comms -> index 1, the first of them
    ev = sw.evaluate(x, y, rff_params=built.rff_params)
    assert int(ev["bits"][1]) == int(ev["bits"][2]) == int(ev["bits"][3])
    assert picks[0] == 1
    # the rule prefers fewer bits over fewer transmissions: the quantized
    # cells transmit at least as often but pay far fewer bits
    assert int(ev["bits"][1]) < int(ev["bits"][0])


def test_cell_config_roundtrips_policies_and_censor_knobs(built):
    """Satellite: `cell_config(i)` must reproduce exactly the config that
    fitted cell i — explicit policy cells come back as `comm=` (legacy
    knobs cleared), numeric (v, mu) cells as the censor knobs — so
    `fit(sw.cell_config(i))` re-runs the very same cell."""
    chain_grid = [Chain([Censor(0.5, 0.97), Quantize(4.0)]),
                  Chain([Censor(0.1, 0.99), Quantize(8.0)])]
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), chain_grid,
               problem=built.problem)
    for i, chain in enumerate(chain_grid):
        cfg = sw.cell_config(i)
        assert cfg.comm == chain
        assert cfg.censor_v is None and cfg.censor_mu is None
        assert cfg.resolved_comm == chain
    # numeric-pair cells (no stored policies) round-trip as censor knobs
    import dataclasses
    pair_grid = ((0.5, 0.97), (0.1, 0.99))
    sw2 = sweep(BASE.replace(censor_v=None, censor_mu=None), pair_grid,
                problem=built.problem)
    sw2 = dataclasses.replace(sw2, policies=())
    for i, (v, mu) in enumerate(pair_grid):
        cfg = sw2.cell_config(i)
        assert cfg.comm is None
        # censors ride the SweepResult as float32 — equal to f32 precision
        assert cfg.resolved_censor == pytest.approx((v, mu), rel=1e-6)


def test_select_tie_breaks_equal_bits_on_comms_then_index(built):
    """Satellite: the full tie-break ladder. With test MSEs forced into a
    tie (huge allowed gap) and bits histories forced equal, the rule must
    fall through to fewest COMMS; with comms also tied, to the lowest
    index — pinned by surgically editing a real sweep's histories."""
    import dataclasses

    grid = ((0.5, 0.97), (0.05, 0.999), (0.3, 0.98))
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    x, y = built.x_test, built.y_test
    G, T = sw.history["bits"].shape

    # equal bits everywhere -> comms decides
    bits_tied = dict(sw.history, bits=jnp.ones((G, T), jnp.float32))
    tied = dataclasses.replace(sw, history=bits_tied)
    idx, _ = tied.select(x, y, max_mse_gap=100.0,
                         rff_params=built.rff_params)
    comms = np.asarray(sw.history["comms"][:, -1])
    assert idx == int(np.flatnonzero(comms == comms.min())[0])

    # equal bits AND equal comms -> lowest index wins, stably
    all_tied = dict(bits_tied, comms=jnp.ones((G, T), jnp.int32))
    tied = dataclasses.replace(sw, history=all_tied)
    picks = [tied.select(x, y, max_mse_gap=100.0,
                         rff_params=built.rff_params)[0]
             for _ in range(3)]
    assert picks == [0, 0, 0]


def test_select_on_sweep_with_zero_transmissions(built):
    """Satellite: a grid whose censor thresholds are so large that NO agent
    ever transmits must still select deterministically (all cells tie at
    0 bits / 0 comms -> lowest qualifying index), not divide-by-zero or
    rank garbage."""
    grid = ((1e9, 1.0), (1e9, 1.0))
    sw = sweep(BASE.replace(censor_v=None, censor_mu=None), grid,
               problem=built.problem)
    ev = sw.evaluate(built.x_test, built.y_test,
                     rff_params=built.rff_params)
    np.testing.assert_array_equal(np.asarray(ev["comms"]), [0, 0])
    np.testing.assert_array_equal(np.asarray(ev["bits"]), [0.0, 0.0])
    idx, model = sw.select(built.x_test, built.y_test, max_mse_gap=10.0,
                           rff_params=built.rff_params)
    assert idx == 0
    assert np.isfinite(float(model.evaluate(built.x_test,
                                            built.y_test)["test_mse"]))
