"""The matrix-free big-D path: CG-vs-Cholesky parity on every backend,
no-(D, D)-materialization pinning, feature-sharded fit/predict parity
(multi-device subprocess), primal-mode validation, and the lazy
CommState defaults."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_results_match

from repro.api import FitConfig, KRRConfig, build_problem, fit
from repro.core import admm

RING = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=40, num_features=512,
                  lam=1e-2, rho=0.1, seed=0),
    graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
    num_iters=40)


@pytest.fixture(scope="module")
def ring512():
    return build_problem(RING)


# ---------------------------------------------------------------------------
# (b) CG-vs-Cholesky parity, pinned
# ---------------------------------------------------------------------------

def test_cg_matches_cholesky_simulator(ring512):
    """Acceptance: at D <= 512 the matrix-free CG primal reproduces the
    exact Cholesky solve to pinned tolerance, with identical censor
    decisions (the send rule sees CG's float-level theta differences only
    through the norm threshold)."""
    chol = fit(RING.replace(primal="cholesky"), problem=ring512.problem)
    cg = fit(RING.replace(primal="cg"), problem=ring512.problem)
    assert_results_match(chol, cg, exact=("comms",), theta_atol=1e-4,
                         close={"train_mse": dict(rtol=1e-4)},
                         err="cholesky-vs-cg")


@pytest.mark.parametrize("backend", ["spmd", "fused"])
def test_cg_matches_cholesky_distributed(ring512, backend):
    """Acceptance, distributed legs: primal='cg' on the ring runtimes runs
    the SAME exact solve (via the consensus primal_solve hook), so it must
    match the simulator's Cholesky trajectory — unlike the legacy one-step
    inexact update, which only approximates it."""
    chol = fit(RING.replace(primal="cholesky"), problem=ring512.problem)
    dist = fit(RING.replace(primal="cg", backend=backend),
               problem=ring512.problem)
    assert_results_match(chol, dist, exact=("comms",), theta_atol=2e-4,
                         err=f"cholesky-vs-cg:{backend}")


def test_auto_primal_crosses_over():
    assert admm.resolve_primal("auto", 512, "quadratic") == "cholesky"
    assert admm.resolve_primal(
        "auto", admm.CG_CROSSOVER_DIM + 1, "quadratic") == "cg"
    assert admm.resolve_primal("auto", 10 ** 6, "absolute") == "gradient"
    with pytest.raises(ValueError, match="normal equations"):
        admm.resolve_primal("cg", 512, "absolute")
    with pytest.raises(ValueError, match="primal"):
        admm.resolve_primal("newton", 512, "quadratic")


def test_fitconfig_validates_primal_mode(ring512):
    with pytest.raises(ValueError, match="primal"):
        FitConfig(primal="newton")
    with pytest.raises(ValueError, match="never materialize"):
        fit(RING.replace(primal="cholesky", backend="spmd", num_iters=2),
            problem=ring512.problem)
    # forcing an exact (21a) solve on a solver with no (21a) subproblem
    # must fail loudly, not silently run a different update
    for algorithm in ("cta", "online_coke", "ridge_oracle"):
        with pytest.raises(ValueError, match="primal"):
            fit(RING.replace(algorithm=algorithm, primal="cg", num_iters=2),
                problem=ring512.problem)


# ---------------------------------------------------------------------------
# No (D, D) materialization on the CG path
# ---------------------------------------------------------------------------

def test_cg_step_materializes_no_dd_array(ring512):
    """The point of the path: the whole CG iteration's jaxpr contains no
    (D, D)-shaped value, while the Cholesky step's does. The detector is
    the benchmark's — one rule guards both pins."""
    from benchmarks.big_d_bench import count_dd_arrays

    problem, policy = ring512.problem, RING.resolved_comm
    state0 = admm.init_state(problem, policy=policy)
    D = problem.feature_dim

    def cg_step(problem, state):
        return admm.coke_step(problem, policy, state, None, primal="cg")

    assert count_dd_arrays(
        jax.make_jaxpr(cg_step)(problem, state0).jaxpr, D) == 0

    def chol_step(problem, state):
        chol = admm._ridge_factors(problem)
        return admm.coke_step(problem, policy, state, chol)

    assert count_dd_arrays(
        jax.make_jaxpr(chol_step)(problem, state0).jaxpr, D) > 0


# ---------------------------------------------------------------------------
# (c) feature-sharded fit / predict parity (multi-device subprocess)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import numpy as np
    from repro.api import FitConfig, KRRConfig, build_problem, fit
    from repro.launch.mesh import make_host_mesh

    cfg = FitConfig(
        krr=KRRConfig(num_agents=4, samples_per_agent=40, num_features=64,
                      lam=1e-2, rho=0.1, seed=0),
        graph="ring", algorithm="coke", censor_v=0.3, censor_mu=0.97,
        num_iters=30, primal="cg")
    built = build_problem(cfg)
    mesh = make_host_mesh(data=2, model=4)

    for backend in ("simulator", "spmd"):
        b = cfg.replace(backend=backend)
        plain = fit(b, problem=built.problem)
        shard = fit(b, problem=built.problem, mesh=mesh)
        np.testing.assert_allclose(np.asarray(plain.theta),
                                   np.asarray(shard.theta), atol=1e-5,
                                   err_msg=backend)
        np.testing.assert_array_equal(np.asarray(plain.comms),
                                      np.asarray(shard.comms))
        np.testing.assert_array_equal(np.asarray(plain.history["bits"]),
                                      np.asarray(shard.history["bits"]))

    # sharded KernelModel: predict/evaluate parity + KernelServer accepts it
    model = plain.to_model(built.rff_params)
    sharded = model.shard(mesh)
    x = np.asarray(built.x_test).reshape(-1, built.x_test.shape[-1])[:32]
    np.testing.assert_allclose(np.asarray(model.predict(x)),
                               np.asarray(sharded.predict(x)), atol=1e-5)
    from repro.serve import KernelServer
    with KernelServer(sharded, mesh=mesh) as srv:
        np.testing.assert_allclose(srv.predict(x),
                                   np.asarray(model.predict(x)), atol=1e-5)
    print("SHARD-PARITY-OK")
""")


def test_sharded_fit_and_predict_match_unsharded():
    """theta/theta_hat/gamma as (N, D/shards) per device must be a pure
    layout change: same trajectories, same send decisions, same bits, same
    predictions. Runs in a subprocess with 8 forced host devices (the
    in-process test session keeps the host's single real device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARD-PARITY-OK" in out.stdout


# ---------------------------------------------------------------------------
# Lazy CommState defaults (no import-time device arrays)
# ---------------------------------------------------------------------------

def test_state_comm_defaults_are_lazy():
    """The class defaults must not hold a device array (it would be
    allocated at module import, before any jax.config/platform selection,
    and shared across every state instance)."""
    from repro.core.online import OnlineState

    assert admm.COKEState._field_defaults["comm"] is None
    assert OnlineState._field_defaults["comm"] is None


def test_legacy_eager_state_without_comm_still_steps(ring512):
    """Eager legacy callers constructing states positionally (comm=None)
    must still step: ensure_state builds the policy state lazily."""
    problem = ring512.problem
    N, D = problem.num_agents, problem.feature_dim
    z = jnp.zeros((N, D), problem.feats.dtype)
    state = admm.COKEState(z, z, z, jnp.zeros((), jnp.int32),
                           jnp.zeros((), jnp.int32))
    assert state.comm is None
    out = admm.coke_step(problem, RING.resolved_comm, state, None,
                         primal="cg")
    assert out.comm is not None
    assert out.comm.bits.shape == (N,)
