"""Optional-`hypothesis` shim for the property-based tests.

The tier-1 environment does not guarantee `hypothesis` is installed
(`pip install -r requirements-dev.txt` provides it). Test modules import
`given / settings / st / hnp` from here: with hypothesis present these are
the real objects; without it, `@given(...)` replaces the property test with
a skipped placeholder so the rest of the module's tests still collect and
run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            # zero-arg placeholder: the original property test's parameters
            # must not be mistaken for pytest fixtures
            @pytest.mark.skip(reason="hypothesis not installed "
                              "(pip install -r requirements-dev.txt)")
            def skipped():  # pragma: no cover - never executed
                pass

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco

    class _StrategyStub:
        """Answers any strategy-building call with an inert placeholder, so
        module-level `st.floats(...)` / `hnp.arrays(...)` expressions in
        skipped tests still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
    hnp = _StrategyStub()
