"""Censoring primitives (Eqs. 19-20) — property-based."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, hnp, settings, st

from repro.core import comm
from repro.core.censor import (CensorSchedule, censor_decision,
                               masked_broadcast)


@settings(deadline=None, max_examples=50)
@given(hnp.arrays(np.float32, (4, 8), elements=st.floats(-5, 5, width=32)),
       hnp.arrays(np.float32, (4, 8), elements=st.floats(-5, 5, width=32)),
       st.floats(0.0, 10.0))
def test_censor_decision_matches_norm(theta, hat, h):
    send = censor_decision(jnp.asarray(theta), jnp.asarray(hat),
                           jnp.asarray(h))
    expect = np.linalg.norm(hat - theta, axis=-1) >= h
    np.testing.assert_array_equal(np.asarray(send), expect)


@settings(deadline=None, max_examples=50)
@given(hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       hnp.arrays(np.bool_, (5,)))
def test_masked_broadcast_selects_rows(theta, hat, send):
    out = np.asarray(masked_broadcast(jnp.asarray(theta), jnp.asarray(hat),
                                      jnp.asarray(send)))
    for i in range(5):
        np.testing.assert_array_equal(out[i],
                                      theta[i] if send[i] else hat[i])


def test_masked_broadcast_rejects_bad_shapes_and_dtypes():
    theta = jnp.ones((3, 4))
    hat = jnp.ones((3, 4))
    send = jnp.ones((3,), bool)
    with pytest.raises(ValueError, match="scalar"):
        masked_broadcast(jnp.ones(()), jnp.ones(()), jnp.ones((), bool))
    with pytest.raises(ValueError, match="must match"):
        masked_broadcast(theta, jnp.ones((3, 5)), send)
    with pytest.raises(ValueError, match="dtype"):
        masked_broadcast(theta, hat.astype(jnp.float16), send)
    with pytest.raises(ValueError, match="batch shape"):
        # a per-coordinate mask silently broadcasting over the trailing
        # feature axis was the failure mode the guard exists for
        masked_broadcast(theta, hat, jnp.ones((3, 4), bool))
    with pytest.raises(ValueError, match="boolean"):
        masked_broadcast(theta, hat, jnp.ones((3,), jnp.int32))


@settings(deadline=None, max_examples=50)
@given(hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       st.floats(0.0, 4.0), st.integers(2, 8), st.floats(0.0, 1.0),
       st.integers(1, 50))
def test_policy_never_changes_unsent_coordinates(theta, hat, v, bits, p, k):
    """Property: whatever the policy (censor x quantize x drop), an agent
    whose broadcast was not sent-and-delivered keeps its stale value on
    EVERY coordinate — censored updates never leak partial state."""
    chain = comm.Chain((comm.Censor(v, 0.95), comm.Quantize(float(bits)),
                        comm.Drop(p)))
    state = chain.init_state(theta.shape[0])
    out, send, _ = chain.apply(jnp.asarray(theta), jnp.asarray(hat),
                               jnp.asarray(k, jnp.int32), state)
    out = np.asarray(out)
    changed = ~np.all(out == np.asarray(hat), axis=-1)
    # a row only changes if the transmitter sent it...
    assert not np.any(changed & ~np.asarray(send))
    # ...and unchanged rows are the stale copy verbatim
    np.testing.assert_array_equal(out[~changed], np.asarray(hat)[~changed])


def test_schedule_nonincreasing_nonnegative():
    s = CensorSchedule(v=2.0, mu=0.9)
    vals = [float(s(k)) for k in range(50)]
    assert all(v >= 0 for v in vals)
    assert all(vals[i + 1] <= vals[i] for i in range(49))


def test_zero_threshold_always_sends():
    s = CensorSchedule(v=0.0)
    theta = jnp.ones((3, 4))
    hat = jnp.ones((3, 4))  # no change at all
    send = censor_decision(theta, hat, s(10))
    assert bool(jnp.all(send))  # ||xi|| = 0 >= 0 -> transmit


def test_enablement_is_structural_not_a_float_check():
    """Satellite: CensorSchedule.enabled (a static `v > 0`) was deleted —
    the thresholds are traced, so enablement must derive from the policy
    STRUCTURE (a Censor stage being present), never from the float."""
    assert not hasattr(CensorSchedule(v=0.0), "enabled")
    assert not comm.censored(None)                       # broadcast
    assert not comm.censored(comm.Chain(()))             # DKLA's policy
    assert not comm.censored(comm.Quantize(4))           # compress-only
    assert comm.censored(comm.Censor(0.5, 0.97))
    # v == 0 still *structurally* censors (the test is in the loop; it
    # just always passes) — exactly the traced-threshold semantics
    assert comm.censored(comm.Chain((comm.Censor(0.0, 0.9),)))
    assert comm.censored(CensorSchedule(0.0, 0.9))
    # DKLA's view of a censored policy strips the thresholds, not the stage
    dkla = comm.uncensored(comm.as_chain(comm.Censor(2.0, 0.99)))
    assert comm.censored(dkla) and dkla.stages[0].v == 0.0
