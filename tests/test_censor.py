"""Censoring primitives (Eqs. 19-20) — property-based."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, hnp, settings, st

from repro.core.censor import (CensorSchedule, censor_decision,
                               masked_broadcast)


@settings(deadline=None, max_examples=50)
@given(hnp.arrays(np.float32, (4, 8), elements=st.floats(-5, 5, width=32)),
       hnp.arrays(np.float32, (4, 8), elements=st.floats(-5, 5, width=32)),
       st.floats(0.0, 10.0))
def test_censor_decision_matches_norm(theta, hat, h):
    send = censor_decision(jnp.asarray(theta), jnp.asarray(hat),
                           jnp.asarray(h))
    expect = np.linalg.norm(hat - theta, axis=-1) >= h
    np.testing.assert_array_equal(np.asarray(send), expect)


@settings(deadline=None, max_examples=50)
@given(hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       hnp.arrays(np.float32, (5, 6), elements=st.floats(-3, 3, width=32)),
       hnp.arrays(np.bool_, (5,)))
def test_masked_broadcast_selects_rows(theta, hat, send):
    out = np.asarray(masked_broadcast(jnp.asarray(theta), jnp.asarray(hat),
                                      jnp.asarray(send)))
    for i in range(5):
        np.testing.assert_array_equal(out[i],
                                      theta[i] if send[i] else hat[i])


def test_schedule_nonincreasing_nonnegative():
    s = CensorSchedule(v=2.0, mu=0.9)
    vals = [float(s(k)) for k in range(50)]
    assert all(v >= 0 for v in vals)
    assert all(vals[i + 1] <= vals[i] for i in range(49))


def test_zero_threshold_always_sends():
    s = CensorSchedule(v=0.0)
    assert not s.enabled
    theta = jnp.ones((3, 4))
    hat = jnp.ones((3, 4))  # no change at all
    send = censor_decision(theta, hat, s(10))
    assert bool(jnp.all(send))  # ||xi|| = 0 >= 0 -> transmit
