"""The declarative capability table: every unsupported combination in
CONFIG_RULES / RUN_RULES raises through the real admission path (config
construction or driver check) with the rule's named nearest-supported
alternative, the trigger registry below covers every rule (adding a rule
without a trigger fails loudly), and the committed README support-matrix
block matches the generated table (doc-drift pin)."""
import pathlib

import pytest

from repro.api import (Censor, Chain, ChurnSchedule, FitConfig,
                       Personalization, TopologySchedule)
from repro.api.capabilities import (BEGIN_MARK, CONFIG_RULES, END_MARK,
                                    RUN_RULES, check_fit, check_stream,
                                    check_sweep, support_matrix)
from repro.api.registry import get_solver

TOPO = TopologySchedule.circulant_cycle(8, [(1,)])
CHURN = ChurnSchedule(leave=((2, 0),))
PZ = Personalization()
COMM = Chain((Censor(0.3, 0.97),))


def _cfg(**kw):
    return FitConfig(**kw)


def _run(mode, **kw):
    """Build the config, then run it through the driver-scoped check —
    the exact call path fit()/fit_stream()/sweep() take."""
    config = FitConfig(**kw)
    solver = get_solver(config.algorithm)
    {"batch": check_fit, "stream": check_stream,
     "sweep": check_sweep}[mode](config, solver)


#: rule id -> a zero-arg callable that must raise THAT rule's error.
#: Kept exhaustive by test_every_rule_has_a_trigger.
TRIGGERS = {
    # CONFIG_RULES — fire in FitConfig.__post_init__, no solver needed
    "sync-gossip-knobs": lambda: _cfg(participation=0.5),
    "comm-censor-knobs": lambda: _cfg(comm=COMM, censor_v=0.3),
    "personalization-topology": lambda: _cfg(personalization=PZ,
                                             topology=TOPO),
    "personalization-churn": lambda: _cfg(exec="gossip", personalization=PZ,
                                          churn=CHURN),
    # RUN_RULES — fire in the driver admission once the solver resolves
    "solver-backend": lambda: _run("batch", algorithm="ridge_oracle",
                                   backend="spmd"),
    "comm-unaware-solver": lambda: _run("batch", algorithm="cta",
                                        comm=COMM),
    "topology-unaware-solver": lambda: _run("batch", algorithm="cta",
                                            topology=TOPO),
    "primal-unaware-solver": lambda: _run("batch", algorithm="ridge_oracle",
                                          primal="cg"),
    "gossip-unaware-solver": lambda: _run("batch", algorithm="cta",
                                          exec="gossip"),
    "gossip-topology": lambda: _run("batch", algorithm="coke",
                                    exec="gossip", topology=TOPO),
    "churn-fused": lambda: _run("batch", algorithm="coke", exec="gossip",
                                churn=CHURN, backend="fused"),
    "churn-cholesky": lambda: _run("batch", algorithm="coke",
                                   exec="gossip", churn=CHURN,
                                   primal="cholesky"),
    "personalization-unaware-solver": lambda: _run(
        "batch", algorithm="cta", personalization=PZ),
    "personalization-fused": lambda: _run("batch", algorithm="coke",
                                          personalization=PZ,
                                          backend="fused"),
    "personalization-cholesky": lambda: _run("batch", algorithm="coke",
                                             personalization=PZ,
                                             primal="cholesky"),
    "stream-batch-solver": lambda: _run("stream", algorithm="coke"),
    "stream-backend": lambda: _run("stream", algorithm="online_coke",
                                   backend="fused"),
    "stream-topology": lambda: _run("stream", algorithm="online_coke",
                                    topology=TOPO),
    "sweep-streaming": lambda: _run("sweep", algorithm="online_coke"),
    "sweep-backend": lambda: _run("sweep", algorithm="coke",
                                  backend="spmd"),
}

ALL_RULES = {r.id: r for r in CONFIG_RULES + RUN_RULES}


def test_every_rule_has_a_trigger():
    """The table and the trigger registry must cover each other exactly —
    a rule without a trigger is an unpinned rejection, a trigger without
    a rule is a stale test."""
    assert set(TRIGGERS) == set(ALL_RULES)


@pytest.mark.parametrize("rule_id", sorted(TRIGGERS))
def test_unsupported_combination_raises_with_alternative(rule_id):
    """Every unsupported combination raises and the error names the
    nearest supported alternative — verbatim from the rule, so a reworded
    table stays in sync with what users actually see."""
    rule = ALL_RULES[rule_id]
    with pytest.raises(ValueError) as exc:
        TRIGGERS[rule_id]()
    msg = str(exc.value)
    assert "nearest supported:" in msg, msg
    assert rule.alternative in msg, (rule_id, msg)


@pytest.mark.parametrize("rule_id", sorted(TRIGGERS))
def test_trigger_fires_its_own_rule(rule_id):
    """Each trigger fires its OWN rule, not an earlier one that happens to
    overlap — pinning rule precedence in the table: every static fragment
    of the rule's reason (placeholders excised) appears in the error."""
    import re

    rule = ALL_RULES[rule_id]
    with pytest.raises(ValueError) as exc:
        TRIGGERS[rule_id]()
    msg = str(exc.value)
    for frag in re.split(r"\{[a-z_]+\}", rule.reason):
        if len(frag) > 10:
            assert frag in msg, (rule_id, frag, msg)


def test_supported_cells_admit():
    """Spot-check the ✅ side of the matrix through the same entry points:
    combinations the table leaves unmatched must pass admission."""
    _run("batch", algorithm="coke", exec="gossip", churn=CHURN,
         backend="spmd")                       # spmd churn (this PR)
    _run("sweep", algorithm="coke", personalization=PZ)  # pz sweep (this PR)
    _run("stream", algorithm="online_coke", backend="spmd")
    _run("batch", algorithm="coke", topology=TOPO)


def test_readme_matrix_in_sync():
    """The committed README block between the support-matrix markers is
    byte-identical to the generated table; regenerate with
    `PYTHONPATH=src python -m repro.api.capabilities` after rule edits."""
    readme = pathlib.Path(__file__).resolve().parents[1] / "README.md"
    text = readme.read_text()
    start = text.index(BEGIN_MARK)
    end = text.index(END_MARK) + len(END_MARK)
    assert text[start:end] == support_matrix(), (
        "README support matrix drifted from repro.api.capabilities — "
        "run: PYTHONPATH=src python -m repro.api.capabilities")
