"""The deployment surface: `FitResult.to_model()` → `KernelModel`
predict/evaluate/save/load, ref↔fused backend parity, the acceptance
contract that `evaluate` reproduces the pre-refactor benchmark test-MSE,
and the vmapped censor-grid `sweep`."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (FitConfig, KernelModel, KRRConfig, build_problem,
                       fit, predict, sweep)
from repro.core import rff

KRR = KRRConfig(num_agents=5, samples_per_agent=40, num_features=16,
                lam=1e-2, rho=0.5, seed=0)
BASE = FitConfig(krr=KRR, algorithm="coke", censor_v=0.5, censor_mu=0.97,
                 num_iters=40)


@pytest.fixture(scope="module")
def built():
    return build_problem(BASE)


@pytest.fixture(scope="module")
def result(built):
    return fit(BASE, problem=built.problem)


@pytest.fixture(scope="module")
def model(built, result):
    return result.to_model(built.rff_params)


# ---------------------------------------------------------------------------
# to_model construction
# ---------------------------------------------------------------------------

def test_fit_attaches_rff_params_when_building_problem():
    res = fit(BASE)
    m = res.to_model()  # no explicit rff_params needed
    assert m.num_features == KRR.num_features
    assert m.meta["algorithm"] == "coke"
    assert m.meta["censor_v"] == 0.5 and m.meta["censor_mu"] == 0.97


def test_to_model_requires_rff_params_for_prebuilt_problem(built, result):
    assert result.rff_params is None  # fit() was handed the problem
    with pytest.raises(ValueError, match="rff_params"):
        result.to_model()


def test_to_model_consensus_average_and_per_agent(built, result, model):
    np.testing.assert_array_equal(
        np.asarray(model.theta), np.asarray(jnp.mean(result.theta, axis=0)))
    np.testing.assert_array_equal(np.asarray(model.thetas),
                                  np.asarray(result.theta))
    assert model.num_agents == KRR.num_agents
    lean = result.to_model(built.rff_params, include_per_agent=False)
    assert lean.thetas is None and lean.num_agents is None
    with pytest.raises(ValueError, match="per-agent"):
        lean.predict(jnp.ones((2, model.input_dim)), agent=0)


# ---------------------------------------------------------------------------
# predict: shapes, chunking, backends
# ---------------------------------------------------------------------------

def test_predict_matches_manual_scoring(built, model):
    x = built.x_test[0]  # (S, d)
    manual = rff.featurize(model.rff_params, x) @ model.theta
    np.testing.assert_array_equal(np.asarray(model.predict(x)),
                                  np.asarray(manual))
    # a bare vector scores to a scalar
    assert model.predict(x[0]).shape == ()
    # agent-specific scoring uses that agent's theta
    manual2 = rff.featurize(model.rff_params, x) @ model.thetas[2]
    np.testing.assert_array_equal(np.asarray(model.predict(x, agent=2)),
                                  np.asarray(manual2))


def test_predict_chunked_matches_single_pass(built, model):
    x = built.x_test  # (N, S, d): leading dims preserved
    full = model.predict(x)
    assert full.shape == x.shape[:-1]
    for bs in (1, 7, 10_000):
        np.testing.assert_allclose(np.asarray(model.predict(x, batch_size=bs)),
                                   np.asarray(full), atol=1e-6)
    with pytest.raises(ValueError, match="batch_size"):
        model.predict(x, batch_size=0)


def test_predict_ref_fused_backend_parity(built, model):
    """Acceptance: ref vs fused (Pallas rff) parity on the scoring path."""
    x = built.x_test
    ref = model.predict(x, backend="ref")
    fused = model.predict(x, backend="fused")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fused), atol=1e-5)
    with pytest.raises(ValueError, match="backend"):
        model.predict(x, backend="tpu_v9")


def test_fused_backend_rejects_cos_sin_mapping(model):
    import jax
    p = rff.draw_rff(jax.random.PRNGKey(0), 3, 8, mapping="cos_sin")
    m = KernelModel(rff_params=p, theta=jnp.zeros(8))
    with pytest.raises(ValueError, match="cos_bias"):
        m.predict(jnp.ones((2, 3)), backend="fused")


def test_api_predict_accepts_model_and_fitresult(model):
    res = fit(BASE)
    x = jnp.ones((3, model.input_dim))
    np.testing.assert_array_equal(
        np.asarray(predict(res, x)),
        np.asarray(res.to_model().predict(x)))
    np.testing.assert_array_equal(np.asarray(predict(model, x)),
                                  np.asarray(model.predict(x)))


# ---------------------------------------------------------------------------
# evaluate: the paper's test protocol
# ---------------------------------------------------------------------------

def test_evaluate_reproduces_legacy_benchmark_test_mse(built, result, model):
    """Acceptance: KernelModel.evaluate == the pre-refactor benchmark
    formula (per-agent einsum over precomputed test features)."""
    preds = jnp.einsum("ntd,nd->nt", built.feats_test, result.theta)
    legacy = float(jnp.mean((built.labels_test - preds) ** 2))
    metrics = model.evaluate(built.x_test, built.y_test)
    assert metrics["test_mse"] == legacy
    assert metrics["per_agent_mse"].shape == (KRR.num_agents,)
    assert metrics["rmse"] == pytest.approx(legacy ** 0.5)
    # consensus scoring is also reported (what a deployed node serves)
    assert metrics["consensus_mse"] > 0.0


def test_evaluate_flat_inputs_use_consensus_theta(built, model):
    x = built.x_test.reshape(-1, model.input_dim)
    y = built.y_test.reshape(-1)
    metrics = model.evaluate(x, y)
    preds = model.predict(x)
    assert metrics["test_mse"] == pytest.approx(
        float(jnp.mean((y - preds) ** 2)))
    assert metrics["consensus_mse"] == metrics["test_mse"]


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def test_save_load_roundtrips_bit_identically(tmp_path, built, model):
    path = str(tmp_path / "artifacts" / "coke_model")
    model.save(path)
    loaded = KernelModel.load(path)
    for a, b in ((model.theta, loaded.theta),
                 (model.thetas, loaded.thetas),
                 (model.rff_params.omega, loaded.rff_params.omega),
                 (model.rff_params.bias, loaded.rff_params.bias)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert loaded.rff_params.mapping == model.rff_params.mapping
    assert loaded.bandwidth == model.bandwidth
    assert loaded.meta == model.meta
    x = built.x_test[0]
    np.testing.assert_array_equal(np.asarray(model.predict(x)),
                                  np.asarray(loaded.predict(x)))


def test_save_load_without_per_agent_thetas(tmp_path, built, result):
    lean = result.to_model(built.rff_params, include_per_agent=False)
    path = str(tmp_path / "lean")
    lean.save(path)
    assert KernelModel.load(path).thetas is None


def test_load_rejects_foreign_artifact(tmp_path):
    import json
    path = str(tmp_path / "other")
    with open(path + ".model.json", "w") as f:
        json.dump({"format": "something/else"}, f)
    with pytest.raises(ValueError, match="not a KernelModel"):
        KernelModel.load(path)


# ---------------------------------------------------------------------------
# sweep: the vmapped censor grid
# ---------------------------------------------------------------------------

GRID = ((0.1, 0.99), (0.5, 0.97), (1.5, 0.95))


def test_sweep_matches_individual_fits(built):
    sw = sweep(BASE, GRID, problem=built.problem)
    assert len(sw) == 3
    assert sw.history["train_mse"].shape == (3, BASE.num_iters)
    for gi, (v, mu) in enumerate(GRID):
        r = fit(BASE.replace(censor_v=v, censor_mu=mu),
                problem=built.problem)
        np.testing.assert_allclose(np.asarray(sw.history["train_mse"][gi]),
                                   np.asarray(r.train_mse), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(sw.history["comms"][gi]),
                                      np.asarray(r.comms))
        # vmapped Cholesky solves differ from the scalar loop at float32 lsb
        np.testing.assert_allclose(np.asarray(sw.thetas[gi]),
                                   np.asarray(r.theta), atol=1e-5)


def test_sweep_accepts_config_list_and_exports_models():
    configs = [BASE.replace(censor_v=v, censor_mu=mu) for v, mu in GRID]
    sw = sweep(configs)  # builds the problem itself -> models need no params
    models = sw.models()
    assert len(models) == 3
    assert all(isinstance(m, KernelModel) for m in models)
    assert models[1].meta["censor_v"] == pytest.approx(0.5)
    with pytest.raises(ValueError, match="censor"):
        sweep([BASE, BASE.replace(num_iters=10)])


def test_sweep_select_picks_cheapest_good_cell(built):
    sw = sweep(BASE, GRID, problem=built.problem)
    ev = sw.evaluate(built.x_test, built.y_test,
                     rff_params=built.rff_params)
    assert ev["test_mse"].shape == (3,)
    idx, m = sw.select(built.x_test, built.y_test, max_mse_gap=10.0,
                       rff_params=built.rff_params)
    # with a huge allowed gap, the cheapest-comms cell wins outright
    assert idx == int(jnp.argmin(ev["comms"]))
    assert isinstance(m, KernelModel)


def test_sweep_rejects_spmd_backend_and_empty_grid(built):
    with pytest.raises(ValueError, match="simulator"):
        sweep(BASE.replace(backend="spmd", graph="ring"), GRID)
    with pytest.raises(ValueError, match="empty"):
        sweep(BASE, ())
    with pytest.raises(ValueError, match="grid"):
        sweep(BASE)
