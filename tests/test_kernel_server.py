"""`repro.serve.KernelServer`: microbatch coalescing, padding-bucket
correctness, backend parity, error isolation, and lifecycle."""
import threading

import numpy as np
import pytest

from repro.api import FitConfig, KRRConfig, fit
from repro.serve import KernelServeConfig, KernelServer

BASE = FitConfig(
    krr=KRRConfig(num_agents=4, samples_per_agent=30, num_features=16,
                  lam=1e-2, rho=0.5, seed=0),
    algorithm="coke", censor_v=0.5, censor_mu=0.97, num_iters=30)


@pytest.fixture(scope="module")
def model():
    return fit(BASE).to_model()


@pytest.fixture(scope="module")
def queries(model):
    rng = np.random.default_rng(0)
    return rng.uniform(size=(64, model.input_dim)).astype(np.float32)


def test_served_predictions_match_model(model, queries):
    direct = np.asarray(model.predict(queries))
    with KernelServer(model) as server:
        out = server.predict(queries)
        np.testing.assert_allclose(out, direct, atol=1e-6)
        # scalar requests resolve to scalars
        assert np.asarray(server.predict(queries[0])).shape == ()


def test_microbatching_coalesces_queued_requests(model, queries):
    """Requests enqueued before the collector starts are scored in one
    padded device call, each future receiving exactly its rows."""
    server = KernelServer(model, KernelServeConfig(max_delay_ms=1.0),
                          autostart=False)
    futs = [server.submit(queries[i:i + 3]) for i in range(0, 63, 3)]
    server.start()
    outs = np.concatenate([f.result() for f in futs])
    server.stop()
    np.testing.assert_allclose(outs, np.asarray(model.predict(queries[:63])),
                               atol=1e-6)
    stats = server.stats()
    assert stats["requests"] == 21
    assert stats["batches"] == 1          # all 21 coalesced
    assert stats["rows"] == 63
    assert stats["padded_rows"] == 128 - 63  # padded up to the 128 bucket


def test_concurrent_submitters_all_get_correct_rows(model, queries):
    direct = np.asarray(model.predict(queries))
    results = {}

    def client(i, server):
        results[i] = server.submit(queries[i * 8:(i + 1) * 8]).result()

    with KernelServer(model, KernelServeConfig(max_delay_ms=5.0)) as server:
        threads = [threading.Thread(target=client, args=(i, server))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for i in range(8):
        np.testing.assert_allclose(results[i], direct[i * 8:(i + 1) * 8],
                                   atol=1e-6)


def test_fused_backend_parity(model, queries):
    with KernelServer(model) as ref_srv:
        ref = ref_srv.predict(queries)
    with KernelServer(model,
                      KernelServeConfig(backend="fused")) as fused_srv:
        fused = fused_srv.predict(queries)
    np.testing.assert_allclose(ref, fused, atol=1e-5)


def test_oversized_batch_spills_past_largest_bucket(model):
    rng = np.random.default_rng(1)
    big = rng.uniform(size=(40, model.input_dim)).astype(np.float32)
    cfg = KernelServeConfig(max_batch=16, buckets=(8, 16))
    server = KernelServer(model, cfg, autostart=False)
    fut = server.submit(big)  # single request larger than max_batch
    server.start()
    out = fut.result()
    server.stop()
    np.testing.assert_allclose(out, np.asarray(model.predict(big)),
                               atol=1e-6)


def test_no_device_call_exceeds_largest_bucket(model, queries):
    """Satellite (batching contract): oversize flushes — a single over-max
    request, or collector overshoot from the final coalesced request —
    must be sliced into bucket-shaped device calls. Every scored shape is
    one of the configured buckets, so the jitted scorer compiles at most
    |buckets| shapes and never retraces on ragged traffic."""
    rng = np.random.default_rng(2)
    cfg = KernelServeConfig(max_batch=16, buckets=(8, 16), max_delay_ms=20.0)
    server = KernelServer(model, cfg, autostart=False)
    shapes = []
    inner = server._score
    server._score = lambda xs: (shapes.append(xs.shape[0]), inner(xs))[1]
    big = rng.uniform(size=(41, model.input_dim)).astype(np.float32)
    futs = [server.submit(big)]
    # plus a pile of small requests: the collector overshoots max_batch
    # by whatever the last one brought
    futs += [server.submit(queries[i:i + 7]) for i in range(0, 35, 7)]
    server.start()
    outs = [f.result() for f in futs]
    server.stop()
    np.testing.assert_allclose(outs[0], np.asarray(model.predict(big)),
                               atol=1e-6)
    for j, f in enumerate(outs[1:]):
        np.testing.assert_allclose(
            f, np.asarray(model.predict(queries[j * 7:(j + 1) * 7])),
            atol=1e-6)
    assert shapes, "no device calls recorded"
    assert max(shapes) <= max(server._buckets)
    assert set(shapes) <= set(server._buckets)


def test_bad_request_fails_its_future_only(model, queries):
    with KernelServer(model) as server:
        with pytest.raises(ValueError, match="queries"):
            server.submit(np.zeros((2, 99), np.float32))
        # the server keeps serving after the rejected request
        np.testing.assert_allclose(server.predict(queries[:4]),
                                   np.asarray(model.predict(queries[:4])),
                                   atol=1e-6)


def test_stop_drains_queued_requests(model, queries):
    """Requests accepted before stop() must resolve even if the collector
    never picked them up — stop() scores the queue remainder inline."""
    server = KernelServer(model, autostart=False)
    futs = [server.submit(queries[i:i + 2]) for i in range(0, 10, 2)]
    server.stop()  # worker never started; drain must resolve every future
    outs = np.concatenate([f.result(timeout=5) for f in futs])
    np.testing.assert_allclose(outs, np.asarray(model.predict(queries[:10])),
                               atol=1e-6)


def test_stopped_server_rejects_submissions(model, queries):
    server = KernelServer(model)
    server.predict(queries[:2])
    server.stop()
    server.stop()  # idempotent
    with pytest.raises(RuntimeError, match="stopped"):
        server.submit(queries[:2])


def test_config_validation():
    with pytest.raises(ValueError, match="backend"):
        KernelServeConfig(backend="quantum")
    with pytest.raises(ValueError, match="buckets"):
        KernelServeConfig(buckets=(128, 32))
